"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]

First layer is dense (d_ff=10944); experts are 1408-wide.
"""
from repro.configs.base import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    remat="full",
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  expert_d_ff=1408, first_dense_layers=1, dense_d_ff=10944,
                  capacity_factor=1.25, group_size=1024),
)

REDUCED = FULL.replace(
    name="deepseek-moe-16b-reduced",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=64, vocab_size=512, head_dim=32, remat="none",
    moe=MoEConfig(num_experts=8, num_shared_experts=2, top_k=2,
                  expert_d_ff=64, first_dense_layers=1, dense_d_ff=256,
                  capacity_factor=2.0, group_size=64),
)
