"""Model configuration dataclasses for the architecture zoo.

Every assigned architecture is expressed as a ``ModelConfig``. One config
file per arch lives in this package; ``repro.configs.get_config(name)``
returns the full-size config and ``get_config(name, reduced=True)`` a
CPU-smoke-test-sized config of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0          # per-expert FFN width
    first_dense_layers: int = 0   # leading dense layers before MoE starts
    dense_d_ff: int = 0           # FFN width of the leading dense layers
    capacity_factor: float = 1.25
    group_size: int = 1024        # tokens per dispatch group
    aux_loss_weight: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64             # SSM state size per head
    d_conv: int = 4               # short conv width
    expand: int = 2               # d_inner = expand * d_model
    head_dim: int = 64            # mamba2 head dim
    chunk_size: int = 128         # SSD chunk length
    attn_every: int = 0           # hybrid: one (shared) attention layer every N
    shared_attn: bool = False     # share the attention block weights


@dataclass(frozen=True)
class XLSTMConfig:
    # alternating (mLSTM, sLSTM) super-blocks
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 64


@dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 12
    # modality frontend is a STUB: input_specs() provides precomputed
    # frame/patch embeddings of shape (batch, frontend_len, d_model)
    frontend_len_ratio: float = 0.25   # encoder frames = seq_len * ratio


@dataclass(frozen=True)
class VisionConfig:
    cross_attn_every: int = 5     # one cross-attn image layer every N layers
    num_image_tokens: int = 2048  # stubbed patch-embedding length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    attn_type: str = "gqa"        # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    mtp_depth: int = 0            # multi-token-prediction extra depth (train only)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionConfig] = None
    # True when sequence mixing is sub-quadratic (eligible for long_500k)
    subquadratic: bool = False
    # preferred optimizer at production scale ("adamw" | "adafactor")
    optimizer: str = "adamw"
    remat: str = "none"           # none | full | dots (activation checkpointing)
    # ghost-head padding: pad (q, kv) head counts to a TP-divisible layout
    # with structurally-zero weights + an output mask — mathematically the
    # identical function, but attention stays head-sharded on the model
    # axis (EXPERIMENTS.md §Perf A2). 0 = off; else the TP width target.
    pad_heads_to_tp: int = 0
    # KV-cache storage dtype for decode: "bf16" | "int8" (per-head-per-
    # position scales; halves cache bytes — EXPERIMENTS.md §Perf C3)
    kv_cache_dtype: str = "bf16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


def ghost_head_layout(num_heads: int, num_kv_heads: int, tp: int
                      ) -> Tuple[int, int, int]:
    """Smallest padded layout (q', kv', rep') with q' = kv' * rep'
    divisible by ``tp``, kv' >= kv, rep' >= rep. Real q head (g, r) maps
    to real kv group g (g < kv, r < rep); pad positions carry zero
    weights and are masked out of the block output."""
    rep = num_heads // num_kv_heads
    best = None
    for kvp in range(num_kv_heads, 4 * num_kv_heads + tp + 1):
        for repp in range(rep, 4 * rep + tp + 1):
            q = kvp * repp
            if q % tp == 0 and q >= num_heads:
                if best is None or q < best[0] or \
                        (q == best[0] and kvp < best[1]):
                    best = (q, kvp, repp)
    assert best is not None
    return best[0], best[1], best[2]
