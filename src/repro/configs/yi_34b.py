"""yi-34b — llama-architecture dense GQA transformer. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="yi-34b", family="dense",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    rope_theta=5_000_000.0, remat="full",
)

REDUCED = FULL.replace(
    name="yi-34b-reduced",
    num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, remat="none",
)
