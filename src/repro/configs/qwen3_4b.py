"""qwen3-4b — GQA with q/k RMSNorm, decoupled head_dim. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0, remat="full",
)

REDUCED = FULL.replace(
    name="qwen3-4b-reduced",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32, remat="none",
)
