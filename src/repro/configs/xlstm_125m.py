"""xlstm-125m — alternating sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

Attention-free: sequence mixing is recurrent (sub-quadratic), so the
long_500k cell RUNS for this arch. d_ff=0 per assignment — the xLSTM blocks
carry their own up/down projections (proj factors in XLSTMConfig).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

FULL = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    attn_type="none", subquadratic=True, remat="full",
    xlstm=XLSTMConfig(),
)

REDUCED = FULL.replace(
    name="xlstm-125m-reduced",
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=2, vocab_size=512,
    xlstm=XLSTMConfig(chunk_size=16),
)
