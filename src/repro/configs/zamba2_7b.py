"""zamba2-7b — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242; unverified]

81 layers = 13 super-blocks of (5 mamba2 + 1 shared-weight attention) + 3
trailing mamba2 layers. Mamba2 state is O(1) in seq len, so long_500k RUNS;
the shared attention layers keep a (sharded) full KV cache at 500k — see
DESIGN.md §5. The attention block weights are shared across applications
(Zamba2's signature trick).
"""
from repro.configs.base import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    subquadratic=True, remat="full",
    ssm=SSMConfig(d_state=64, attn_every=6, shared_attn=True),
)

REDUCED = FULL.replace(
    name="zamba2-7b-reduced",
    num_layers=9, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, head_dim=32, remat="none",
    ssm=SSMConfig(d_state=16, attn_every=3, shared_attn=True, chunk_size=16,
                  head_dim=16),
)
