"""CLI for the repo-native static checker.

Usage::

    python -m repro.analysis                      # human output, exit 1
    python -m repro.analysis --format=json        # machine-readable
    python -m repro.analysis --write-baseline     # accept current findings
    python -m repro.analysis --rules parity/raw-score-sort,locks/...

Exit code 0 when every finding is baselined (or none exist), 1
otherwise.  The baseline lives at ``<root>/analysis_baseline.json``;
prefer inline ``# analysis: allow[rule-id] reason`` comments for sites
that are intentional forever.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.findings import (load_baseline, render_text,
                                     save_baseline, split_baselined)
from repro.analysis.model import RepoModel
from repro.analysis.registry import all_rules, run_rules

BASELINE_NAME = "analysis_baseline.json"


def detect_root() -> Path:
    cwd = Path.cwd()
    if (cwd / "src" / "repro").is_dir():
        return cwd
    # src/repro/analysis/__main__.py -> repo root is three levels up
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (auto-detected by default)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default <root>/{BASELINE_NAME})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings into the baseline")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in sorted(all_rules().values(), key=lambda r: r.id):
            print(f"{r.id:35s} [{r.family}] {r.title}")
        return 0

    root = (args.root or detect_root()).resolve()
    baseline_path = args.baseline or (root / BASELINE_NAME)
    ids = [s.strip() for s in args.rules.split(",")] if args.rules else None

    model = RepoModel(root)
    findings = run_rules(model, ids)

    if args.write_baseline:
        save_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new = split_baselined(findings, baseline)

    if args.format == "json":
        print(json.dumps({
            "rules": len(ids) if ids else len(all_rules()),
            "findings": [f.to_dict() for f in findings],
            "new": [f.fingerprint for f in new],
            "baselined": len(findings) - len(new),
            "exit": 1 if new else 0,
        }, indent=2))
    else:
        sys.stdout.write(render_text(findings, new))
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
