"""Repo model for ``repro.analysis``: parsed ASTs + source for every
Python file under the analysis roots, plus the CI workflow text.

The model is path-based, not import-based — nothing under analysis is
ever imported, so rules run identically on the real tree and on the
known-bad fixture corpora in ``tests/fixtures/analysis/``.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional

SKIP_DIRS = {".git", "__pycache__", ".venv", "node_modules", "build"}

_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\[([^\]]+)\]")


class FileModel:
    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.parse_error: Optional[str] = None
        try:
            self.tree: Optional[ast.Module] = ast.parse(self.source)
        except SyntaxError as e:  # surfaced as a finding by the engine
            self.tree = None
            self.parse_error = str(e)
        self._parents: Optional[Dict[ast.AST, ast.AST]] = None

    @property
    def module_name(self) -> str:
        return Path(self.rel).stem

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def allowed_rules(self, line: int) -> List[str]:
        """Inline suppressions: ``# analysis: allow[rule-id] reason`` on
        the flagged line or in the contiguous comment block above it
        (multi-line justifications stay suppressions)."""
        out: List[str] = []
        if 1 <= line <= len(self.lines):
            for m in _ALLOW_RE.finditer(self.lines[line - 1]):
                out.extend(p.strip() for p in m.group(1).split(","))
        ln = line - 1
        while 1 <= ln <= len(self.lines) and \
                self.lines[ln - 1].lstrip().startswith("#"):
            for m in _ALLOW_RE.finditer(self.lines[ln - 1]):
                out.extend(p.strip() for p in m.group(1).split(","))
            ln -= 1
        return out

    def parents(self) -> Dict[ast.AST, ast.AST]:
        if self._parents is None:
            p: Dict[ast.AST, ast.AST] = {}
            for node in ast.walk(self.tree) if self.tree else ():
                for child in ast.iter_child_nodes(node):
                    p[child] = node
            self._parents = p
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        p = self.parents()
        cur = p.get(node)
        while cur is not None:
            yield cur
            cur = p.get(cur)


class RepoModel:
    """All Python files under ``root`` (skipping tests/benchmarks for the
    real tree: rules govern library code) plus CI workflow text."""

    def __init__(self, root: Path):
        self.root = Path(root).resolve()
        self.files: List[FileModel] = []
        self.workflows: Dict[str, str] = {}
        self._load()

    def _load(self) -> None:
        src = self.root / "src"
        scan_root = src if src.is_dir() else self.root
        for path in sorted(scan_root.rglob("*.py")):
            if any(part in SKIP_DIRS for part in path.parts):
                continue
            rel = path.relative_to(self.root).as_posix()
            self.files.append(FileModel(path, rel))
        wf_dir = self.root / ".github" / "workflows"
        if wf_dir.is_dir():
            for wf in sorted(wf_dir.glob("*.yml")):
                self.workflows[wf.name] = wf.read_text()
        # test sources referenced by CI sweeps (coverage checks only)
        self.test_sources: Dict[str, str] = {}
        tdir = self.root / "tests"
        if tdir.is_dir():
            for t in sorted(tdir.glob("test_*.py")):
                self.test_sources["tests/" + t.name] = t.read_text()

    def in_scope(self, fm: FileModel, *dirnames: str) -> bool:
        parts = Path(fm.rel).parts
        return any(d in parts for d in dirnames)

    def scoped(self, *dirnames: str) -> List[FileModel]:
        return [f for f in self.files
                if f.tree is not None and self.in_scope(f, *dirnames)]

    def by_module(self, name: str) -> Optional[FileModel]:
        for f in self.files:
            if f.module_name == name and f.tree is not None:
                return f
        return None
