"""repro.analysis — repo-native static checker for the parity,
concurrency, kernel-contract and plan invariants the parity guarantees
rest on.  CLI: ``python -m repro.analysis``; runtime plan validation:
``repro.analysis.plan_validator.validate_plan``.
"""
from repro.analysis.findings import Finding  # noqa: F401
from repro.analysis.model import RepoModel  # noqa: F401
from repro.analysis.registry import all_rules, run_rules  # noqa: F401
