"""Lightweight intra-repo call graph for the lock-discipline rules.

Resolution is name-based (no type inference): ``self.m()`` binds within
the enclosing class, ``self.store.m()`` / ``store.m()`` bind through a
caller-supplied receiver->class hint table, module-qualified calls bind
through per-file import aliases, and bare calls bind within the module.
That covers the store/scheduler/visibility topology this repo actually
has; unresolved calls are simply absent edges (the checker stays
conservative in the direction of fewer false positives).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.model import FileModel, RepoModel


class FuncInfo:
    def __init__(self, qual: str, fm: FileModel, node: ast.AST,
                 cls: Optional[str]):
        self.qual = qual
        self.fm = fm
        self.node = node
        self.cls = cls


class CallGraph:
    def __init__(self, model: RepoModel,
                 recv_hints: Optional[Dict[str, str]] = None):
        self.model = model
        self.recv_hints = dict(recv_hints or {})
        self.funcs: Dict[str, FuncInfo] = {}
        self.methods: Dict[Tuple[str, str], str] = {}   # (class, meth)->qual
        self.mod_funcs: Dict[Tuple[str, str], str] = {}  # (module, fn)->qual
        self.edges: Dict[str, Set[str]] = {}
        self._index()
        self._link()

    # ------------------------------------------------------------- index
    def _index(self) -> None:
        for fm in self.model.files:
            if fm.tree is None:
                continue
            mod = fm.module_name
            for node in fm.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{mod}::{node.name}"
                    self.funcs[qual] = FuncInfo(qual, fm, node, None)
                    self.mod_funcs[(mod, node.name)] = qual
                elif isinstance(node, ast.ClassDef):
                    for item in node.body:
                        if isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                            qual = f"{mod}::{node.name}.{item.name}"
                            self.funcs[qual] = FuncInfo(qual, fm, item,
                                                        node.name)
                            self.methods[(node.name, item.name)] = qual

    @staticmethod
    def _import_aliases(fm: FileModel) -> Dict[str, str]:
        """local alias -> module basename (``vis_lib`` -> ``visibility``)."""
        out: Dict[str, str] = {}
        for node in ast.walk(fm.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = \
                        a.name.split(".")[-1]
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    out[a.asname or a.name] = a.name
        return out

    def _resolve_call(self, call: ast.Call, mod: str,
                      cls: Optional[str], aliases: Dict[str, str]
                      ) -> Optional[str]:
        f = call.func
        if isinstance(f, ast.Name):
            q = self.mod_funcs.get((mod, f.id))
            if q is not None:
                return q
            target_mod = aliases.get(f.id)
            if target_mod is not None:          # from mod import fn
                for (m, fn), q in self.mod_funcs.items():
                    if fn == f.id and m == target_mod:
                        return q
            return None
        if not isinstance(f, ast.Attribute):
            return None
        meth, recv = f.attr, f.value
        if isinstance(recv, ast.Name):
            if recv.id == "self" and cls is not None:
                q = self.methods.get((cls, meth))
                if q is not None:
                    return q
            hint = self.recv_hints.get(recv.id)
            if hint is not None:
                return self.methods.get((hint, meth))
            target_mod = aliases.get(recv.id)
            if target_mod is not None:
                return self.mod_funcs.get((target_mod, meth))
        elif isinstance(recv, ast.Attribute) and \
                isinstance(recv.value, ast.Name) and recv.value.id == "self":
            hint = self.recv_hints.get(recv.attr)
            if hint is not None:
                return self.methods.get((hint, meth))
        return None

    def _link(self) -> None:
        for qual, info in self.funcs.items():
            mod = info.fm.module_name
            aliases = self._import_aliases(info.fm)
            targets: Set[str] = set()
            for node in ast.walk(info.node):
                if isinstance(node, ast.Call):
                    t = self._resolve_call(node, mod, info.cls, aliases)
                    if t is not None and t != qual:
                        targets.add(t)
            self.edges[qual] = targets

    # --------------------------------------------------------- reachable
    def reachable(self, roots: List[str]) -> Set[str]:
        seen: Set[str] = set()
        stack = [r for r in roots if r in self.funcs]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            stack.extend(self.edges.get(cur, ()))
        return seen

    def path_hint(self, root: str, target: str) -> str:
        """Short ``root -> ... -> target`` chain for finding messages."""
        prev: Dict[str, str] = {}
        stack = [root]
        seen = {root}
        while stack:
            cur = stack.pop()
            if cur == target:
                chain = [target]
                while chain[-1] != root:
                    chain.append(prev[chain[-1]])
                names = [c.split("::")[-1] for c in reversed(chain)]
                return " -> ".join(names)
            for nxt in self.edges.get(cur, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    prev[nxt] = cur
                    stack.append(nxt)
        return Path(target).name
