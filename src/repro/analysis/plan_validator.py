"""Runtime structural validation of planner output (``validate_plan``).

The static side of the plan family (``repro.analysis.rules.plans``)
checks the planner's *source*; this module checks the planner's
*output*: every plan the optimizer emits must satisfy the operator
contracts the executor silently assumes.  Violations raise
``PlanContractError`` — a validated plan either executes correctly or
never executes at all.

Contracts checked:

- the plan kind is one the executor dispatches on;
- NN-shaped plans carry ranks and a positive ``k``; search-shaped plans
  never carry a fused/quantized dispatch;
- union kinds carry search-shaped subplans (and only union kinds do);
- no predicate appears in both ``indexed`` and ``residual`` (it would
  be applied twice, double-charging selectivity);
- fused dispatch: scan-shaped kind, a single positive-weight
  vector/spatial rank, ``0 < k <= KMAX`` (the kernel's top-k register
  budget);
- quantized dispatch: additionally a vector rank, ``pq_m > 0`` and a
  refine ladder whose ``refine * k`` survivor set still fits ``KMAX``;
- graph dispatch: additionally a vector rank, positive out-degree and
  hop count, ``k <= beam <= KMAX`` (the beam survivors are the re-rank
  candidate set), and never combined with the quantized dispatch;
- the operator tree finishes candidates in visibility order: top-k
  truncation happens ABOVE the memtable overlay, which sits ABOVE
  visibility resolution (TopKMerge -> MemtableOverlay ->
  VisibilityResolve on the root path) — pruning before visibility can
  drop a winner that a shadowed candidate displaced.

Wiring: the planner calls ``maybe_validate`` on every plan it returns
when ``REPRO_VALIDATE_PLANS=1`` (CI bench smokes set it); tests assert
``validate_plan`` directly over every TRACY template.
"""
from __future__ import annotations

import os
from typing import List

KNOWN_KINDS = {
    "full_scan", "index_intersect", "prefilter_nn", "postfilter_nn",
    "nra", "full_scan_nn", "union", "union_nn", "empty",
}
NN_KINDS = {"prefilter_nn", "postfilter_nn", "nra", "full_scan_nn",
            "union_nn"}
SEARCH_KINDS = {"full_scan", "index_intersect", "union"}
UNION_KINDS = {"union", "union_nn"}
# kinds the fused / quantized packed-scan dispatch may attach to
SCAN_NN_KINDS = {"full_scan_nn", "prefilter_nn", "union_nn"}


class PlanContractError(AssertionError):
    """A plan violates an executor contract (see module docstring)."""

    def __init__(self, plan, problems: List[str]):
        self.plan = plan
        self.problems = problems
        bullet = "\n  - ".join(problems)
        super().__init__(
            f"plan kind={getattr(plan, 'kind', '?')!r} violates "
            f"{len(problems)} contract(s):\n  - {bullet}")


def _pred_key(p) -> tuple:
    col = getattr(p, "col", None)
    return (type(p).__name__, col, id(p) if col is None else 0)


def _check_dispatch(plan, problems: List[str]) -> None:
    from repro.core import query as q
    from repro.kernels import fused_scan as fs_kernel
    kmax = int(fs_kernel.KMAX)
    if plan.kind not in SCAN_NN_KINDS:
        problems.append(
            f"fused/quantized dispatch on kind {plan.kind!r} — only "
            f"scan-shaped NN kinds {sorted(SCAN_NN_KINDS)} pack segments")
    if len(plan.ranks) != 1:
        problems.append(
            f"fused dispatch needs exactly one rank, got "
            f"{len(plan.ranks)} (the kernel ranks a single monotone "
            f"distance)")
    else:
        r = plan.ranks[0]
        if not isinstance(r, (q.VectorRank, q.SpatialRank)):
            problems.append(
                f"fused dispatch over a {type(r).__name__} rank — only "
                f"vector/spatial distances stream through the kernel")
        elif not getattr(r, "weight", 1.0) > 0:
            problems.append("fused dispatch with a non-positive rank "
                            "weight (distance would rank inverted)")
        if plan.quantized and not isinstance(r, q.VectorRank):
            problems.append("quantized dispatch requires a vector rank "
                            "(ADC tables are per-subspace codebooks)")
        if getattr(plan, "graph", False) and \
                not isinstance(r, q.VectorRank):
            problems.append("graph dispatch requires a vector rank "
                            "(the CSR graph is a vector proximity graph)")
    if not 0 < plan.k <= kmax:
        problems.append(
            f"fused dispatch with k={plan.k} outside (0, KMAX={kmax}] — "
            f"the kernel's top-k registers can't hold the result")
    if plan.quantized:
        if plan.pq_m <= 0:
            problems.append(f"quantized dispatch with pq_m={plan.pq_m}")
        if plan.refine < 2:
            problems.append(
                f"quantized dispatch with refine={plan.refine} < 2 — "
                f"the exact re-rank needs headroom over k")
        elif plan.refine * plan.k > kmax:
            problems.append(
                f"quantized survivor set refine*k={plan.refine * plan.k} "
                f"exceeds KMAX={kmax}")
    if getattr(plan, "graph", False):
        if plan.quantized:
            problems.append(
                "graph and quantized dispatch on one plan — the executor "
                "groups by a single candidate-generation strategy")
        if plan.graph_r <= 0:
            problems.append(f"graph dispatch with R={plan.graph_r}")
        if plan.graph_hops <= 0:
            problems.append(
                f"graph dispatch with hops={plan.graph_hops} — the "
                f"traversal would never leave the entry points")
        if not plan.k <= plan.graph_beam <= kmax:
            problems.append(
                f"graph beam={plan.graph_beam} outside [k={plan.k}, "
                f"KMAX={kmax}] — the beam survivors are the re-rank "
                f"candidate set")


def _check_tree(plan, problems: List[str]) -> None:
    from repro.core import operators as ops_lib
    try:
        root = plan.operator_tree()
    except Exception as e:  # tree construction itself is part of the check
        problems.append(f"operator tree construction failed: {e!r}")
        return
    if plan.kind == "empty":
        if not isinstance(root, ops_lib.EmptyResult):
            problems.append(
                f"kind 'empty' must render an EmptyResult root, got "
                f"{type(root).__name__}")
        return
    # walk the root finisher chain: TopKMerge (NN only) above
    # MemtableOverlay above VisibilityResolve
    node = root
    if plan.kind in NN_KINDS:
        if not isinstance(node, ops_lib.TopKMerge):
            problems.append(
                f"NN plan root must be TopKMerge (truncation happens "
                f"last), got {type(node).__name__}")
            return
        node = node.children[0] if node.children else None
    if not isinstance(node, ops_lib.MemtableOverlay):
        problems.append(
            f"expected MemtableOverlay below the root (unflushed rows "
            f"must join before truncation), got "
            f"{type(node).__name__ if node else None}")
        return
    node = node.children[0] if node.children else None
    if not isinstance(node, ops_lib.VisibilityResolve):
        problems.append(
            f"expected VisibilityResolve below MemtableOverlay (top-k "
            f"over unresolved versions can keep a shadowed row), got "
            f"{type(node).__name__ if node else None}")


def validate_plan(plan) -> None:
    """Raise ``PlanContractError`` if ``plan`` violates any executor
    contract; a clean pass returns None."""
    problems: List[str] = []
    kind = getattr(plan, "kind", None)
    if kind not in KNOWN_KINDS:
        raise PlanContractError(plan, [
            f"unknown plan kind {kind!r} — executor dispatch would fall "
            f"through to the generic shape"])

    if kind in NN_KINDS:
        if not plan.ranks and kind != "union_nn":
            problems.append(f"NN kind {kind!r} with no ranks")
        if plan.k <= 0:
            problems.append(f"NN kind {kind!r} with k={plan.k}")
    if kind in SEARCH_KINDS and (plan.fused or plan.quantized or
                                 getattr(plan, "graph", False)):
        problems.append(
            f"search kind {kind!r} carries a scan dispatch — "
            f"there is no scan->top-k to fuse")

    if kind in UNION_KINDS:
        if not plan.subplans:
            problems.append(f"{kind!r} with no subplans (DNF must have "
                            f"at least one conjunct)")
        for i, sp in enumerate(plan.subplans):
            if sp.kind not in ("full_scan", "index_intersect"):
                problems.append(
                    f"subplan[{i}] has kind {sp.kind!r} — union children "
                    f"must be search-shaped (the OR-merge unions bitmaps)")
            overlap = [c for c in sp.indexed if c in sp.residual]
            if overlap:
                problems.append(
                    f"subplan[{i}] applies predicate(s) twice "
                    f"(indexed AND residual): {overlap}")
    elif plan.subplans:
        problems.append(f"kind {kind!r} carries {len(plan.subplans)} "
                        f"subplans — only union kinds fan out over DNF")

    overlap = [p for p in plan.indexed if p in plan.residual]
    if overlap:
        problems.append(
            f"predicate(s) in both indexed and residual: {overlap} — "
            f"selectivity is charged twice and NOT probes are unsound")

    if plan.fused or plan.quantized or getattr(plan, "graph", False):
        _check_dispatch(plan, problems)

    _check_tree(plan, problems)

    if problems:
        raise PlanContractError(plan, problems)


def validation_enabled() -> bool:
    return os.environ.get("REPRO_VALIDATE_PLANS", "") not in ("", "0")


def maybe_validate(plan):
    """Planner hook: validate when REPRO_VALIDATE_PLANS=1, pass through
    otherwise.  Returns the plan so call sites stay expressions."""
    if validation_enabled():
        validate_plan(plan)
    return plan
