"""Rule registry + engine for ``repro.analysis``.

Rules are plain functions ``fn(model) -> List[Finding]`` registered with
the ``@rule`` decorator.  The engine fills in family/snippet/fingerprint,
applies inline ``# analysis: allow[rule-id]`` suppressions, and reports
syntax errors as findings instead of crashing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.analysis.findings import Finding, fingerprint_findings
from repro.analysis.model import FileModel, RepoModel

RuleFn = Callable[[RepoModel], List[Finding]]


@dataclasses.dataclass
class Rule:
    id: str
    family: str
    title: str
    fn: RuleFn


RULES: Dict[str, Rule] = {}


def rule(id: str, family: str, title: str) -> Callable[[RuleFn], RuleFn]:
    def deco(fn: RuleFn) -> RuleFn:
        if id in RULES:
            raise ValueError(f"duplicate rule id {id}")
        RULES[id] = Rule(id, family, title, fn)
        return fn
    return deco


def finding(rule_id: str, fm: FileModel, line: int, message: str) -> Finding:
    return Finding(rule=rule_id, family=RULES[rule_id].family, path=fm.rel,
                   line=line, message=message, snippet=fm.line_text(line))


def _load_rules() -> None:
    # importing the rule modules populates RULES via the decorator
    from repro.analysis.rules import (durability, graph,  # noqa: F401
                                      kernels, locks, obs, parity, plans)


def run_rules(model: RepoModel, ids: Optional[List[str]] = None
              ) -> List[Finding]:
    _load_rules()
    selected = [RULES[i] for i in ids] if ids else list(RULES.values())
    out: List[Finding] = []
    for fm in model.files:
        if fm.parse_error is not None:
            out.append(Finding(rule="engine/syntax-error", family="engine",
                               path=fm.rel, line=1, message=fm.parse_error))
    for r in selected:
        out.extend(r.fn(model))
    # inline suppressions
    by_rel = {fm.rel: fm for fm in model.files}
    kept: List[Finding] = []
    for f in out:
        fm = by_rel.get(f.path)
        if fm is not None:
            allows = fm.allowed_rules(f.line)
            if f.rule in allows or "*" in allows:
                continue
        kept.append(f)
    fingerprint_findings(kept)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept


def all_rules() -> Dict[str, Rule]:
    _load_rules()
    return dict(RULES)
