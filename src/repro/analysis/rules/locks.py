"""Lock-discipline rules (family: locks).

Background mode (``pipeline=True, background=True``) runs flushes and
compactions on a daemon worker thread while the writer keeps ingesting
and query threads keep reading.  Everything the worker publishes —
segment lists, metrics, the global index, the visibility cache, PQ
codebooks — must happen under the store lock, and module-level caches
shared across threads must be guarded.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.asthelpers import dotted_name, under_lock
from repro.analysis.callgraph import CallGraph
from repro.analysis.findings import Finding
from repro.analysis.model import RepoModel
from repro.analysis.registry import finding, rule

# store-level shared mutable state the flush worker may publish
STORE_FIELDS = {
    "segments", "sealed", "memtable", "metrics", "global_index",
    "_mt_cache", "_mt_epoch", "_vis_cache", "_pq_books", "unique_pks",
    "_seen_max_pk", "_seqno",
}
MUTATORS = {"append", "pop", "clear", "extend", "insert", "remove",
            "update", "setdefault", "popitem", "move_to_end", "add",
            "discard"}
GLOBAL_INDEX_MUTATORS = {"on_new_segment", "on_drop_segment",
                         "add_segment", "drop_segment"}
# module functions that mutate store state through their first argument
WRITE_FUNCS = {"extend_cache_on_flush": "_vis_cache"}

RECV_HINTS = {"store": "LSMStore", "scheduler": "FlushScheduler"}


def _store_field(node: ast.AST, cls: Optional[str]) -> Optional[str]:
    """Monitored field name when ``node`` is ``<store-like>.<field>``."""
    if not isinstance(node, ast.Attribute) or node.attr not in STORE_FIELDS:
        return None
    recv = node.value
    if isinstance(recv, ast.Name):
        if recv.id == "self" and cls == "LSMStore":
            return node.attr
        if recv.id == "store":
            return node.attr
    if isinstance(recv, ast.Attribute) and recv.attr == "store" and \
            isinstance(recv.value, ast.Name) and recv.value.id == "self":
        return node.attr
    return None


def _writes_in(fn_node: ast.AST, cls: Optional[str]
               ) -> List[Tuple[ast.AST, str]]:
    out: List[Tuple[ast.AST, str]] = []
    for n in ast.walk(fn_node):
        if isinstance(n, (ast.Assign, ast.AugAssign)):
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in targets:
                base = t.value if isinstance(t, ast.Subscript) else t
                field = _store_field(base, cls)
                if field is not None:
                    out.append((n, field))
        elif isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Attribute):
                field = _store_field(f.value, cls)
                if field is not None and (
                        f.attr in MUTATORS or
                        (field == "global_index" and
                         f.attr in GLOBAL_INDEX_MUTATORS)):
                    out.append((n, field))
            leaf = dotted_name(f).split(".")[-1]
            if leaf in WRITE_FUNCS:
                out.append((n, WRITE_FUNCS[leaf]))
    return out


@rule("locks/worker-unlocked-write", "locks",
      "flush-worker-reachable store mutations must hold the store lock")
def worker_unlocked_write(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    cg = CallGraph(model, recv_hints=RECV_HINTS)
    root = next((q for q in cg.funcs
                 if q.endswith("::FlushScheduler._run_worker")), None)
    if root is None:
        return out
    # which shared state the query side reaches (context for messages)
    query_roots = [q for q in cg.funcs
                   if q.endswith("::Executor.execute_many") or
                   q.endswith("::nra_topk") or
                   q.endswith("::run_scan_group") or
                   q.endswith("::visibility_index")]
    query_reach = cg.reachable(query_roots)
    query_fields: Set[str] = set()
    for qual in query_reach:
        info = cg.funcs[qual]
        for n in ast.walk(info.node):
            field = _store_field(n, info.cls)
            if field is not None:
                query_fields.add(field)
    for qual in sorted(cg.reachable([root])):
        info = cg.funcs[qual]
        for node, field in _writes_in(info.node, info.cls):
            if under_lock(info.fm, node):
                continue
            shared = " (also reached by query threads)" \
                if field in query_fields else ""
            out.append(finding(
                "locks/worker-unlocked-write", info.fm, node.lineno,
                f"write to store.{field} outside the store lock, "
                f"reachable from the flush worker via "
                f"{cg.path_hint(root, qual)}{shared}"))
    return out


_CONTAINER_CTORS = {"dict", "set", "list", "OrderedDict", "defaultdict",
                    "Counter", "deque"}


def _module_containers(fm) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for node in fm.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            t, v = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            t, v = node.target, node.value
        else:
            continue
        if not isinstance(t, ast.Name):
            continue
        is_container = isinstance(v, (ast.Dict, ast.Set, ast.List)) or (
            isinstance(v, ast.Call) and
            dotted_name(v.func).split(".")[-1] in _CONTAINER_CTORS)
        if is_container:
            out[t.id] = node.lineno
    return out


@rule("locks/global-mutable-cache", "locks",
      "module-level caches shared across threads must be lock-guarded")
def global_mutable_cache(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    for fm in model.scoped("core", "kernels"):
        containers = _module_containers(fm)
        if not containers:
            continue
        for fn in ast.walk(fm.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for n in ast.walk(fn):
                name = None
                if isinstance(n, (ast.Assign, ast.AugAssign)):
                    targets = n.targets if isinstance(n, ast.Assign) \
                        else [n.target]
                    for t in targets:
                        if isinstance(t, ast.Subscript) and \
                                isinstance(t.value, ast.Name) and \
                                t.value.id in containers:
                            name = t.value.id
                elif isinstance(n, ast.Call) and \
                        isinstance(n.func, ast.Attribute) and \
                        isinstance(n.func.value, ast.Name) and \
                        n.func.value.id in containers and \
                        n.func.attr in MUTATORS:
                    name = n.func.value.id
                if name is None or under_lock(fm, n):
                    continue
                out.append(finding(
                    "locks/global-mutable-cache", fm, n.lineno,
                    f"module-level container `{name}` mutated without a "
                    f"lock — query and flush threads share it "
                    f"(cross-thread LRU/memo corruption)"))
    return out
