"""Kernel-contract rules (family: kernel).

The seven ``pl.pallas_call`` sites share one tile vocabulary (BLOCK_Q=8,
BLOCK_N=512, KMAX=128, int32 sentinel for the pk tie-break range).
These rules verify the constants agree across kernel modules, every
BlockSpec index map matches the grid rank, operand/spec/out_shape counts
line up, and tiled wrappers guard divisibility with asserts.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.asthelpers import (const_int, dotted_name,
                                       enclosing_function, lambda_arity,
                                       local_assignment)
from repro.analysis.findings import Finding
from repro.analysis.model import FileModel, RepoModel
from repro.analysis.registry import finding, rule

TILE_CONSTANTS = ("BLOCK_Q", "BLOCK_N", "KMAX")
EXPECTED = {"BLOCK_Q": 8, "BLOCK_N": 512, "KMAX": 128}


def _module_consts(fm: FileModel) -> Dict[str, Tuple[int, Optional[int]]]:
    """name -> (lineno, int value or None for non-literal)."""
    out: Dict[str, Tuple[int, Optional[int]]] = {}
    for node in fm.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if name in TILE_CONSTANTS or name == "SENTINEL":
                out[name] = (node.lineno, const_int(node.value))
    return out


def _imported_consts(fm: FileModel) -> Dict[str, str]:
    """tile-constant name -> source module, for ``from X import BLOCK_N``."""
    out: Dict[str, str] = {}
    for node in ast.walk(fm.tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                if a.name in TILE_CONSTANTS or a.name == "SENTINEL":
                    out[a.asname or a.name] = node.module.split(".")[-1]
    return out


@rule("kernel/tile-constants", "kernel",
      "tile/grid constants must agree across kernel modules")
def tile_constants(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    kfiles = [f for f in model.scoped("kernels") if f.module_name != "ops"]
    canon_fm = next((f for f in kfiles if "KMAX" in _module_consts(f)), None)
    if canon_fm is None:
        return out
    canon = _module_consts(canon_fm)
    for name, want in EXPECTED.items():
        ln, val = canon.get(name, (1, None))
        if val is not None and val != want:
            out.append(finding(
                "kernel/tile-constants", canon_fm, ln,
                f"{name}={val} in the canonical kernel module, contract "
                f"expects {want}"))
    sent = canon.get("SENTINEL")
    if sent is None or "int32" not in canon_fm.line_text(sent[0]):
        out.append(finding(
            "kernel/tile-constants", canon_fm,
            sent[0] if sent else 1,
            "SENTINEL must be the int32 max (pk tie-break range is "
            "int32; larger pks overflow the packed id columns)"))
    for fm in kfiles:
        if fm is canon_fm:
            continue
        consts = _module_consts(fm)
        imports = _imported_consts(fm)
        for name in TILE_CONSTANTS:
            if name in consts and name in imports:
                out.append(finding(
                    "kernel/tile-constants", fm, consts[name][0],
                    f"{name} both imported from {imports[name]} and "
                    f"redefined locally — single-source it"))
            elif name in consts:
                ln, val = consts[name]
                canon_val = canon.get(name, (0, None))[1]
                if val is not None and canon_val is not None and \
                        val != canon_val:
                    out.append(finding(
                        "kernel/tile-constants", fm, ln,
                        f"{name}={val} disagrees with the canonical "
                        f"{canon_fm.module_name}.{name}={canon_val} — "
                        f"import it or document why the tile differs"))
    return out


def _spec_list(node: Optional[ast.AST], func: Optional[ast.AST]
               ) -> Optional[List[ast.AST]]:
    """Normalize in_specs/out_specs/out_shape to a list of elements,
    resolving a local ``name = [...]`` one step."""
    if node is None:
        return None
    if isinstance(node, ast.Name) and func is not None:
        node = local_assignment(func, node.id) or node
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


def _grid_rank(node: Optional[ast.AST], func: Optional[ast.AST]
               ) -> Optional[int]:
    if node is None:
        return None
    if isinstance(node, ast.Name) and func is not None:
        node = local_assignment(func, node.id) or node
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _blockspec_parts(node: ast.AST
                     ) -> Tuple[Optional[int], Optional[int]]:
    """(block rank, index-map lambda arity) for a BlockSpec call."""
    if not isinstance(node, ast.Call) or \
            dotted_name(node.func).split(".")[-1] != "BlockSpec":
        return None, None
    rank = None
    if node.args and isinstance(node.args[0], (ast.Tuple, ast.List)):
        rank = len(node.args[0].elts)
    arity = lambda_arity(node.args[1]) if len(node.args) > 1 else None
    return rank, arity


def _shape_rank(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Call) and node.args and \
            isinstance(node.args[0], (ast.Tuple, ast.List)):
        return len(node.args[0].elts)
    return None


@rule("kernel/pallas-call-contract", "kernel",
      "pallas_call specs must match grid rank, operands and out_shapes")
def pallas_call_contract(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    for fm in model.scoped("kernels"):
        parents = fm.parents()
        for node in ast.walk(fm.tree):
            if not (isinstance(node, ast.Call) and
                    dotted_name(node.func).endswith("pallas_call")):
                continue
            func = enclosing_function(fm, node)
            kw = {k.arg: k.value for k in node.keywords}
            rank = _grid_rank(kw.get("grid"), func)
            in_specs = _spec_list(kw.get("in_specs"), func)
            out_specs = _spec_list(kw.get("out_specs"), func)
            out_shape = _spec_list(kw.get("out_shape"), func)
            ln = node.lineno
            if rank is not None:
                for spec in (in_specs or []) + (out_specs or []):
                    srank, arity = _blockspec_parts(spec)
                    if arity is not None and arity != rank:
                        out.append(finding(
                            "kernel/pallas-call-contract", fm, spec.lineno,
                            f"BlockSpec index map takes {arity} args but "
                            f"the grid has rank {rank}"))
                    if srank is not None and arity is not None and \
                            srank < 1:
                        out.append(finding(
                            "kernel/pallas-call-contract", fm, spec.lineno,
                            "empty BlockSpec block shape"))
            if out_specs is not None and out_shape is not None and \
                    len(out_specs) != len(out_shape):
                out.append(finding(
                    "kernel/pallas-call-contract", fm, ln,
                    f"{len(out_specs)} out_specs vs {len(out_shape)} "
                    f"out_shape entries"))
            if out_specs is not None and out_shape is not None:
                for spec, shp in zip(out_specs, out_shape):
                    srank, _ = _blockspec_parts(spec)
                    orank = _shape_rank(shp)
                    if srank is not None and orank is not None and \
                            srank != orank:
                        out.append(finding(
                            "kernel/pallas-call-contract", fm, spec.lineno,
                            f"out BlockSpec rank {srank} != out_shape "
                            f"rank {orank}"))
            parent = parents.get(node)
            if in_specs is not None and isinstance(parent, ast.Call) and \
                    parent.func is node:
                n_ops = len(parent.args)
                if n_ops != len(in_specs):
                    out.append(finding(
                        "kernel/pallas-call-contract", fm, ln,
                        f"{n_ops} operands passed but {len(in_specs)} "
                        f"in_specs declared"))
    return out


@rule("kernel/grid-divisibility-guard", "kernel",
      "tiled wrappers must assert operand divisibility by the tile")
def grid_divisibility_guard(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    for fm in model.scoped("kernels"):
        for node in ast.walk(fm.tree):
            if not (isinstance(node, ast.Call) and
                    dotted_name(node.func).endswith("pallas_call")):
                continue
            func = enclosing_function(fm, node)
            if func is None:
                continue
            kw = {k.arg: k.value for k in node.keywords}
            grid = kw.get("grid")
            if isinstance(grid, ast.Name):
                grid = local_assignment(func, grid.id)
            if grid is None:
                continue
            divisors = []
            for n in ast.walk(grid):
                if isinstance(n, ast.BinOp) and \
                        isinstance(n.op, ast.FloorDiv):
                    divisors.extend(x.id for x in ast.walk(n.right)
                                    if isinstance(x, ast.Name))
            guarded = set()
            for n in ast.walk(func):
                if isinstance(n, ast.Assert):
                    for b in ast.walk(n.test):
                        if isinstance(b, ast.BinOp) and \
                                isinstance(b.op, ast.Mod):
                            guarded.update(
                                x.id for x in ast.walk(b.right)
                                if isinstance(x, ast.Name))
            for d in divisors:
                if d not in guarded:
                    out.append(finding(
                        "kernel/grid-divisibility-guard", fm, node.lineno,
                        f"grid divides by {d} but the wrapper never "
                        f"asserts the operand is a multiple of {d} — "
                        f"ragged tails silently truncate"))
    return out
