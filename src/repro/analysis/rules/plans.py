"""Plan-contract rules (family: plan).

The static half of the plan family: every ``Plan(kind=...)`` literal the
planner can emit must be matched somewhere by a ``.kind`` dispatch
(operator-tree construction or executor routing) — a constructed kind no
dispatcher ever names is a typo'd dead plan shape.  The runtime half
(``validate_plan``) lives in ``repro.analysis.plan_validator`` and is
asserted over every TRACY template in tests plus the CI bench smokes.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.asthelpers import dotted_name
from repro.analysis.findings import Finding
from repro.analysis.model import RepoModel
from repro.analysis.registry import finding, rule


def _constructed_kinds(model: RepoModel
                       ) -> List[Tuple[str, object, int]]:
    out = []
    for fm in model.scoped("core"):
        for node in ast.walk(fm.tree):
            if not isinstance(node, ast.Call):
                continue
            if dotted_name(node.func).split(".")[-1] != "Plan":
                continue
            for kwarg in node.keywords:
                if kwarg.arg == "kind" and \
                        isinstance(kwarg.value, ast.Constant) and \
                        isinstance(kwarg.value.value, str):
                    out.append((kwarg.value.value, fm, node.lineno))
    return out


def _handled_kinds(model: RepoModel) -> Set[str]:
    """String literals tested against a ``.kind`` attribute anywhere."""
    handled: Set[str] = set()
    for fm in model.scoped("core"):
        for node in ast.walk(fm.tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left, *node.comparators]
            has_kind = any(isinstance(s, ast.Attribute) and s.attr == "kind"
                           for s in sides)
            if not has_kind:
                continue
            for s in sides:
                if isinstance(s, ast.Constant) and isinstance(s.value, str):
                    handled.add(s.value)
                elif isinstance(s, (ast.Tuple, ast.List, ast.Set)):
                    for e in s.elts:
                        if isinstance(e, ast.Constant) and \
                                isinstance(e.value, str):
                            handled.add(e.value)
    return handled


@rule("plan/kind-dispatch", "plan",
      "every constructed Plan kind must be matched by a .kind dispatch")
def kind_dispatch(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    constructed = _constructed_kinds(model)
    if not constructed:
        return out
    handled = _handled_kinds(model)
    seen: Dict[str, bool] = {}
    for kind, fm, ln in constructed:
        if kind in handled or seen.get(kind):
            continue
        seen[kind] = True
        out.append(finding(
            "plan/kind-dispatch", fm, ln,
            f"Plan kind '{kind}' is constructed but no dispatcher ever "
            f"compares .kind against it — dead or typo'd plan shape"))
    return out
