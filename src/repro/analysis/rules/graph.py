"""Graph-kernel contract rules (family: graph).

The CSR graph index pads every fixed-degree neighbor row with -1
(``core/index/graph.py``), and jnp gathers clamp negative indices instead
of failing — an unguarded ``jnp.take(x, cand)`` over raw neighbor ids
silently reads row 0 (or row n-1) for every padding lane and corrupts
distances without an error anywhere.  Every kernel that consumes a CSR
therefore masks ``cand >= 0`` (or ``< 0``) BEFORE any gather keyed by the
candidate ids; this rule makes that convention machine-checked.

Detection is function-scoped dataflow-lite: a name is *neighbor-derived*
if it matches the neighbor-array naming convention or is assigned from an
expression that uses a neighbor-derived name; it is *guarded* if it (or a
name in its definition chain) appears in a ``>= 0`` / ``< 0`` comparison
in the same function.  A ``take``/``take_along_axis`` whose index uses an
unguarded neighbor-derived name is a finding.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Set

from repro.analysis.asthelpers import dotted_name, terminal_idents
from repro.analysis.findings import Finding
from repro.analysis.model import RepoModel
from repro.analysis.registry import finding, rule

# names that hold a CSR neighbor matrix in this codebase
NEIGHBOR_RE = re.compile(r"(^|_)(nbr|nbrs|neighbor|neighbors|adj)(_|$|s$)")

GATHER_FUNCS = ("take", "take_along_axis")
_GUARD_OPS = (ast.GtE, ast.Lt)       # x >= 0 / x < 0 padding guards


def _is_zero_guard(node: ast.Compare) -> Set[str]:
    """Names guarded by this comparison when it is a `>= 0` / `< 0`
    (or the mirrored `0 <= x` / `0 > x`) padding check."""
    out: Set[str] = set()
    if len(node.ops) != 1 or len(node.comparators) != 1:
        return out
    op, left, right = node.ops[0], node.left, node.comparators[0]
    def const0(n):
        return isinstance(n, ast.Constant) and n.value == 0
    if isinstance(op, _GUARD_OPS) and const0(right):
        out.update(t for t in terminal_idents(left))
    elif isinstance(op, (ast.LtE, ast.Gt)) and const0(left):
        out.update(t for t in terminal_idents(right))
    return out


def _function_findings(fm, fn: ast.AST) -> List[Finding]:
    assigns: Dict[str, List[ast.AST]] = {}
    for n in ast.walk(fn):
        if isinstance(n, ast.Assign):
            for tgt in n.targets:
                for t in ast.walk(tgt):
                    if isinstance(t, ast.Name):
                        assigns.setdefault(t.id, []).append(n.value)

    # neighbor-derived names: seed on naming convention, close over
    # assignments (a value computed FROM neighbor ids carries the -1
    # padding forward until a guard rewrites it)
    derived: Set[str] = {name for name in assigns
                         if NEIGHBOR_RE.search(name)}
    for a in fn.args.args if hasattr(fn, "args") else []:
        if NEIGHBOR_RE.search(a.arg):
            derived.add(a.arg)
    changed = True
    while changed:
        changed = False
        for name, values in assigns.items():
            if name in derived:
                continue
            for v in values:
                if derived & set(terminal_idents(v)):
                    derived.add(name)
                    changed = True
                    break

    # guarded names: compared against 0, closed over assignments the
    # same way (`safe = where(cand >= 0, cand, 0)` launders the guard
    # into the new name)
    guarded: Set[str] = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Compare):
            guarded |= _is_zero_guard(n)
    changed = True
    while changed:
        changed = False
        for name, values in assigns.items():
            if name in guarded:
                continue
            for v in values:
                if guarded & set(terminal_idents(v)):
                    guarded.add(name)
                    changed = True
                    break

    out: List[Finding] = []
    for n in ast.walk(fn):
        if not (isinstance(n, ast.Call) and
                dotted_name(n.func).split(".")[-1] in GATHER_FUNCS):
            continue
        if len(n.args) < 2:
            continue
        idx = n.args[1]
        bad = [t for t in terminal_idents(idx)
               if t in derived and t not in guarded]
        for name in sorted(set(bad)):
            out.append(finding(
                "graph/neighbor-pad-guard", fm, n.lineno,
                f"gather indexed by neighbor-derived `{name}` with no "
                f">= 0 / < 0 padding guard in scope — -1 CSR padding "
                f"clamps to row 0 and silently corrupts the gather"))
    return out


@rule("graph/neighbor-pad-guard", "graph",
      "CSR-consuming kernels must guard -1 neighbor padding before gather")
def neighbor_pad_guard(model: RepoModel) -> List[Finding]:
    # top-level functions only: nested defs are scanned as part of their
    # enclosing function so closure-captured guards stay visible
    out: List[Finding] = []
    for fm in model.scoped("kernels"):
        for node in fm.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(_function_findings(fm, node))
    return out
