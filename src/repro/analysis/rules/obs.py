"""Observability rules (family: obs).

The span tracer's accounting depends on spans closing exactly once:
``obs_trace.span(...)`` returns a context manager whose ``__exit__``
stamps the duration and attaches the node to its parent (or the ring
buffer).  A span created outside a ``with`` never closes — it either
leaks an open node under the contextvar or silently records nothing —
so engine code must always open spans via ``with``.

Durations must come from the monotonic ``time.perf_counter()`` clock:
``time.time()`` is wall time, which NTP slews and steps, so a latency
histogram fed from it can record negative or wildly wrong intervals.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.asthelpers import dotted_name, enclosing_function
from repro.analysis.findings import Finding
from repro.analysis.model import RepoModel
from repro.analysis.registry import finding, rule


@rule("obs/span-closed", "obs",
      "trace spans in engine code must be opened via `with`")
def span_closed(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    for fm in model.scoped("core"):
        parents = fm.parents()
        for node in ast.walk(fm.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name.split(".")[-1] != "span":
                continue
            parent = parents.get(node)
            if isinstance(parent, ast.withitem) and \
                    parent.context_expr is node:
                continue
            out.append(finding(
                "obs/span-closed", fm, node.lineno,
                f"`{name}(...)` outside a `with` statement — the span "
                f"never closes, so its duration is never recorded and "
                f"the open node can leak under the context variable"))
    return out


def _sub_operand_names(func: ast.AST) -> set:
    """Names that appear as operands of a subtraction inside ``func`` —
    the signature of a duration computation (``t1 - t0``)."""
    names = set()
    for n in ast.walk(func):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Sub):
            for side in (n.left, n.right):
                if isinstance(side, ast.Name):
                    names.add(side.id)
        elif isinstance(n, ast.AugAssign) and isinstance(n.op, ast.Sub) \
                and isinstance(n.target, ast.Name):
            names.add(n.target.id)
    return names


@rule("obs/wall-clock-timing", "obs",
      "durations in engine code must use time.perf_counter()")
def wall_clock_timing(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    for fm in model.scoped("core", "kernels"):
        parents = fm.parents()
        for node in ast.walk(fm.tree):
            if not (isinstance(node, ast.Call)
                    and dotted_name(node.func).endswith("time.time")):
                continue
            parent = parents.get(node)
            in_sub = isinstance(parent, ast.BinOp) and \
                isinstance(parent.op, ast.Sub)
            assigned_for_sub = False
            if isinstance(parent, ast.Assign) and \
                    len(parent.targets) == 1 and \
                    isinstance(parent.targets[0], ast.Name):
                func = enclosing_function(fm, node)
                if func is not None and parent.targets[0].id in \
                        _sub_operand_names(func):
                    assigned_for_sub = True
            if not (in_sub or assigned_for_sub):
                continue    # wall timestamps (log entries etc.) are fine
            out.append(finding(
                "obs/wall-clock-timing", fm, node.lineno,
                "time.time() used to compute a duration — wall time "
                "steps under NTP; use the monotonic "
                "time.perf_counter() for intervals"))
    return out
