"""Durability rules (family: durability).

The publish protocol for every durable artifact in ``core/`` — manifest
generations, segment files, the facade catalog — is write-temp, fsync,
rename: ``os.replace`` makes the new file visible atomically, but only
the preceding ``fsync``/``fdatasync`` guarantees the bytes being
published are on stable storage.  A rename without the sync can publish
a file whose content is still only in the page cache; after a crash the
manifest names a segment (or the catalog names a manifest) whose bytes
never made it to disk — exactly the torn state recovery is supposed to
be immune to.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.asthelpers import dotted_name
from repro.analysis.callgraph import CallGraph
from repro.analysis.findings import Finding
from repro.analysis.model import RepoModel
from repro.analysis.registry import finding, rule

_RENAMES = {"os.replace", "os.rename"}
_SYNCS = {"fsync", "fdatasync"}


def _is_sync_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and \
        dotted_name(node.func).split(".")[-1] in _SYNCS


def _syncing_funcs(cg: CallGraph) -> Set[str]:
    """Functions that (transitively) reach an fsync/fdatasync call."""
    direct = {qual for qual, info in cg.funcs.items()
              if any(_is_sync_call(n) for n in ast.walk(info.node))}
    return {qual for qual in cg.funcs
            if cg.reachable([qual]) & direct}


@rule("durability/fsync-before-publish", "durability",
      "atomic publish renames must fsync the temp file first")
def fsync_before_publish(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    cg = CallGraph(model)
    syncing = _syncing_funcs(cg)
    core_files = {fm.rel for fm in model.scoped("core")}
    for qual, info in cg.funcs.items():
        if info.fm.rel not in core_files:
            continue
        for node in ast.walk(info.node):
            if not (isinstance(node, ast.Call)
                    and dotted_name(node.func) in _RENAMES):
                continue
            # satisfied by a direct fsync/fdatasync earlier in this
            # function, or by calling (anywhere in the def chain) a
            # helper that syncs — e.g. a shared write-and-sync routine
            direct = any(_is_sync_call(n) and n.lineno < node.lineno
                         for n in ast.walk(info.node))
            via_chain = bool((cg.reachable([qual]) - {qual}) & syncing)
            if direct or via_chain:
                continue
            out.append(finding(
                "durability/fsync-before-publish", info.fm, node.lineno,
                f"{dotted_name(node.func)} publishes a file without an "
                f"fsync/fdatasync of its content first — a crash after "
                f"the rename can surface a file whose bytes never left "
                f"the page cache"))
    return out
