"""Parity-contract rules (family: parity).

The bitwise-parity guarantees built in PRs 1-6 hang on conventions:
result ordering via the shared ``(score, pk)`` lexicographic comparator,
distance admission in squared form, and a pure-jnp oracle twin in
``kernels/ref.py`` for every Pallas kernel, exercised by the CI
interpret-mode sweep.  These rules make the conventions machine-checked.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional

from repro.analysis.asthelpers import dotted_name, terminal_idents
from repro.analysis.findings import Finding
from repro.analysis.model import RepoModel
from repro.analysis.registry import finding, rule

# identifiers that carry ranking scores / distances in this codebase
SCORE_RE = re.compile(
    r"^(d|d2|dd|dist|dists|distances|d_exact|flat_d|score|scores|ubs|lbs|"
    r"adc|adc_d)$")

SORT_FUNCS = ("argsort",)          # np.argsort / jnp.argsort / x.argsort
PLAIN_SORTS = ("np.sort", "jnp.sort", "numpy.sort")


def _scoreish(expr: ast.AST) -> bool:
    return any(SCORE_RE.match(t) for t in terminal_idents(expr))


@rule("parity/raw-score-sort", "parity",
      "rank ordering must go through the (score, pk) comparator")
def raw_score_sort(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    msg = ("raw sort on a score/distance array — rank ordering must "
           "tie-break by pk (np.lexsort((pk, score)) or an explicit "
           "(score, pk) key)")
    for fm in model.scoped("core", "kernels"):
        for node in ast.walk(fm.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            leaf = name.split(".")[-1]
            key_expr: Optional[ast.AST] = None
            if leaf in SORT_FUNCS and node.args:
                key_expr = node.args[0]
            elif (name in PLAIN_SORTS or leaf == "sorted") and node.args:
                key_expr = node.args[0]
            elif leaf == "sort" and isinstance(node.func, ast.Attribute) \
                    and name not in PLAIN_SORTS:
                key_expr = node.func.value      # list.sort()
            if key_expr is None:
                continue
            # an explicit key= mentioning pk is the sanctioned comparator
            key_kw = next((kw.value for kw in node.keywords
                           if kw.arg == "key"), None)
            idents = terminal_idents(key_kw) if key_kw is not None else []
            if "pk" in idents or "pks" in idents:
                continue
            if key_kw is not None:
                key_expr = key_kw
            if _scoreish(key_expr):
                out.append(finding("parity/raw-score-sort", fm,
                                   node.lineno, msg))
    return out


_SQRT_FUNCS = ("np.sqrt", "jnp.sqrt", "numpy.sqrt")
_ORDER_OPS = (ast.Lt, ast.LtE, ast.Gt, ast.GtE)


def _is_sqrt_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and dotted_name(node.func) in _SQRT_FUNCS


def _sqrt_hits(expr: ast.AST, sqrt_names: Dict[str, int],
               before_line: int) -> bool:
    """Does ``expr`` use a sqrt-derived *value* (not its len/shape)?"""
    stack = [expr]
    while stack:
        c = stack.pop()
        if isinstance(c, ast.Call):
            if _is_sqrt_call(c):
                return True
            name = dotted_name(c.func)
            if name == "len" or name.endswith(".shape"):
                continue                    # size of the array, not values
        if isinstance(c, ast.Name) and \
                sqrt_names.get(c.id, 10**9) < before_line:
            return True
        stack.extend(ast.iter_child_nodes(c))
    return False


@rule("parity/sqrt-compare", "parity",
      "distance admission must compare in squared form")
def sqrt_compare(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    msg = ("sqrt-derived value feeds an ordering comparison — compare "
           "squared distances against a squared threshold instead (PR 4 "
           "contract; sqrt is monotone, the full-array pass is wasted)")
    for fm in model.scoped("core", "kernels"):
        scopes = [n for n in ast.walk(fm.tree)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in scopes:
            sqrt_names: Dict[str, int] = {}     # name -> first assign line
            for n in ast.walk(fn):
                if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                        isinstance(n.targets[0], ast.Name):
                    if any(_is_sqrt_call(c) for c in ast.walk(n.value)):
                        sqrt_names.setdefault(n.targets[0].id, n.lineno)
            for n in ast.walk(fn):
                if not isinstance(n, ast.Compare) or \
                        not all(isinstance(o, _ORDER_OPS) for o in n.ops):
                    continue
                if any(_sqrt_hits(op, sqrt_names, n.lineno)
                       for op in (n.left, *n.comparators)):
                    out.append(finding("parity/sqrt-compare", fm,
                                       n.lineno, msg))
    return out


# kernel wrapper -> oracle twin names that differ from `<wrapper>_ref`
TWIN_ALIASES = {
    "fused_scan_topk": "fused_topk_ref",
    "quantized_scan_topk": "quantized_topk_ref",
}
# wrapper params the twin does not take / extra twin params that are fine
TWIN_PARAM_IGNORE = {"interpret", "occ"}


def _kernel_wrappers(fm) -> List[ast.FunctionDef]:
    out = []
    for node in fm.tree.body:
        if isinstance(node, ast.FunctionDef) and \
                not node.name.startswith("_"):
            if any(isinstance(c, ast.Call) and
                   dotted_name(c.func).endswith("pallas_call")
                   for c in ast.walk(node)):
                out.append(node)
    return out


def _param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


@rule("parity/twin-kernel", "parity",
      "every Pallas kernel needs a ref.py oracle twin")
def twin_kernel(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    kfiles = model.scoped("kernels")
    ref_fm = next((f for f in kfiles if f.module_name == "ref"), None)
    ref_funcs: Dict[str, ast.FunctionDef] = {}
    if ref_fm is not None:
        ref_funcs = {n.name: n for n in ref_fm.tree.body
                     if isinstance(n, ast.FunctionDef)}
    for fm in kfiles:
        if fm.module_name in ("ref", "ops"):
            continue
        for wrapper in _kernel_wrappers(fm):
            twin_name = TWIN_ALIASES.get(wrapper.name,
                                         wrapper.name + "_ref")
            twin = ref_funcs.get(twin_name)
            if twin is None:
                out.append(finding(
                    "parity/twin-kernel", fm, wrapper.lineno,
                    f"Pallas kernel `{wrapper.name}` has no oracle twin "
                    f"`{twin_name}` in kernels/ref.py"))
                continue
            want = [p for p in _param_names(wrapper)
                    if p not in TWIN_PARAM_IGNORE]
            have = set(_param_names(twin))
            missing = [p for p in want if p not in have]
            if missing:
                out.append(finding(
                    "parity/twin-kernel", fm, wrapper.lineno,
                    f"oracle twin `{twin_name}` signature mismatch: "
                    f"missing params {missing} of `{wrapper.name}`"))
    return out


_TEST_PATH_RE = re.compile(r"tests/[\w./-]+\.py")


def _sweep_test_files(workflow_text: str) -> Optional[List[str]]:
    """Test files named in the REPRO_USE_PALLAS=1 sweep command
    (continuation lines included); None when no sweep exists."""
    lines = workflow_text.splitlines()
    for i, ln in enumerate(lines):
        if "REPRO_USE_PALLAS=1" not in ln:
            continue
        block = [ln]
        j = i
        while lines[j].rstrip().endswith("\\") and j + 1 < len(lines):
            j += 1
            block.append(lines[j])
        return _TEST_PATH_RE.findall("\n".join(block))
    return None


@rule("parity/pallas-ci-sweep", "parity",
      "every Pallas kernel module must be in the interpret-mode CI sweep")
def pallas_ci_sweep(model: RepoModel) -> List[Finding]:
    out: List[Finding] = []
    if not model.workflows:
        return out
    sweep: Optional[List[str]] = None
    wf_name = None
    for name, text in model.workflows.items():
        files = _sweep_test_files(text)
        if files is not None:
            sweep, wf_name = files, name
            break
    kmods = [fm for fm in model.scoped("kernels")
             if fm.module_name not in ("ref", "ops") and _kernel_wrappers(fm)]
    if sweep is None:
        for fm in kmods:
            out.append(finding(
                "parity/pallas-ci-sweep", fm, 1,
                "no REPRO_USE_PALLAS=1 interpret-mode sweep found in CI "
                "workflows — Pallas kernels are untested on the kernel "
                "branch"))
        return out
    for fm in kmods:
        covered = any(fm.module_name in model.test_sources.get(t, "")
                      for t in sweep)
        if not covered:
            out.append(finding(
                "parity/pallas-ci-sweep", fm, 1,
                f"kernel module `{fm.module_name}` is not exercised by "
                f"any test file in the {wf_name} REPRO_USE_PALLAS sweep "
                f"({', '.join(sweep)})"))
    return out
