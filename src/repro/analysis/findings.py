"""Finding model + baseline-file handling for ``repro.analysis``.

A finding is one rule violation anchored to a file/line.  Fingerprints
are content-addressed (rule, path, source line text, occurrence index)
rather than line-number-addressed so a baseline survives unrelated edits
above the flagged line.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Dict, List, Optional


@dataclasses.dataclass
class Finding:
    rule: str            # e.g. "parity/raw-score-sort"
    family: str          # parity | locks | kernel | plan
    path: str            # repo-relative posix path
    line: int            # 1-based
    message: str
    snippet: str = ""    # stripped source line (fingerprint anchor)
    fingerprint: str = ""

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def fingerprint_findings(findings: List[Finding]) -> None:
    """Assign stable fingerprints in place.  Identical (rule, path,
    snippet) triples are disambiguated by occurrence order."""
    seen: Dict[str, int] = {}
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        base = f"{f.rule}|{f.path}|{f.snippet}"
        idx = seen.get(base, 0)
        seen[base] = idx + 1
        h = hashlib.sha1(f"{base}|{idx}".encode()).hexdigest()[:16]
        f.fingerprint = h


def load_baseline(path: Path) -> Dict[str, Dict]:
    """fingerprint -> entry dict.  Missing file = empty baseline."""
    if not path.is_file():
        return {}
    data = json.loads(path.read_text())
    return {e["fingerprint"]: e for e in data.get("findings", [])}


def save_baseline(path: Path, findings: List[Finding]) -> None:
    data = {
        "version": 1,
        "comment": ("Accepted findings. Regenerate with "
                    "`python -m repro.analysis --write-baseline`; prefer "
                    "inline `# analysis: allow[rule-id] reason` comments "
                    "for sites that are intentional forever."),
        "findings": [
            {"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
             "snippet": f.snippet}
            for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
        ],
    }
    path.write_text(json.dumps(data, indent=2) + "\n")


def split_baselined(findings: List[Finding], baseline: Dict[str, Dict]
                    ) -> List[Finding]:
    """Findings not covered by the baseline."""
    return [f for f in findings if f.fingerprint not in baseline]


def render_text(findings: List[Finding], new: Optional[List[Finding]] = None
                ) -> str:
    """Human diff-style rendering: one line per finding, grouped by file."""
    if not findings:
        return "repro.analysis: clean (0 findings)\n"
    new_fps = {f.fingerprint for f in (new if new is not None else findings)}
    out, last = [], None
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        if f.path != last:
            out.append(f"--- {f.path}")
            last = f.path
        mark = "+" if f.fingerprint in new_fps else " "
        out.append(f"{mark} {f.path}:{f.line}: [{f.rule}] {f.message}")
        if f.snippet:
            out.append(f"      | {f.snippet}")
    n_new = len(new) if new is not None else len(findings)
    out.append(f"{len(findings)} finding(s), {n_new} not in baseline")
    return "\n".join(out) + "\n"
