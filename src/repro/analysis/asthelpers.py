"""Small shared AST utilities for the analysis rules."""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

LOCK_TOKENS = ("lock", "_cv")


def dotted_name(node: ast.AST) -> str:
    """``np.argsort`` -> "np.argsort"; unknown shapes -> "" (never raises)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Call):
        return dotted_name(node.func)
    return ""


def terminal_idents(node: ast.AST) -> List[str]:
    """All identifier leaves in an expression: Name ids + Attribute attrs."""
    out: List[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            out.append(n.attr)
    return out


def under_lock(fm, node: ast.AST,
               tokens: Iterable[str] = LOCK_TOKENS) -> bool:
    """True when ``node`` sits lexically inside a ``with`` whose context
    expression mentions a lock-ish name (``_lock``, ``_cv``, ...)."""
    for anc in fm.ancestors(node):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                src = ast.unparse(item.context_expr).lower()
                if any(t in src for t in tokens):
                    return True
    return False


def enclosing_function(fm, node: ast.AST) -> Optional[ast.AST]:
    for anc in fm.ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def local_assignment(func: ast.AST, name: str) -> Optional[ast.expr]:
    """The value of a simple ``name = <expr>`` inside ``func`` (the last
    one wins, matching runtime order for straight-line wrapper code)."""
    found: Optional[ast.expr] = None
    for n in ast.walk(func):
        if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                isinstance(n.targets[0], ast.Name) and \
                n.targets[0].id == name:
            found = n.value
    return found


def lambda_arity(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Lambda):
        a = node.args
        return len(a.posonlyargs) + len(a.args)
    return None


def const_int(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) and \
            not isinstance(node.value, bool):
        return node.value
    return None
