from repro.models.model import (  # noqa: F401
    decode_step, encode, forward, init_cache, init_params, loss_fn,
    param_axes, param_shapes, trunk)
