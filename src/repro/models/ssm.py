"""State-space & recurrent sequence mixers: Mamba2 (SSD) and xLSTM blocks.

Both families are sub-quadratic: full-sequence forward uses a chunkwise
parallel form (O(S * chunk) memory), decode is an O(1) state update —
this is what makes the long_500k cell feasible.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Axes, Params


# ===========================================================================
# Mamba2 (SSD, single group)
# ===========================================================================

def mamba2_init(key, d_model: int, ssm) -> Tuple[Params, Axes]:
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    conv_ch = d_inner + 2 * ssm.d_state
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        # (z, xBC, dt) fused input projection
        "in_proj": layers.dense_init(k1, d_model,
                                     2 * d_inner + 2 * ssm.d_state + n_heads),
        "conv_w": (jax.random.normal(k2, (ssm.d_conv, conv_ch), jnp.float32)
                   * 0.1).astype(layers.DTYPE),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), layers.DTYPE),
        "out_proj": layers.dense_init(k3, d_inner, d_model),
    }
    axes = {
        "in_proj": ("embed", "ff"),
        "conv_w": (None, "ff"),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": ("ff",),
        "out_proj": ("ff", "embed"),
    }
    return params, axes


def _split_zxbcdt(params, y, d_model, ssm):
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    z = y[..., :d_inner]
    xbc = y[..., d_inner:d_inner + d_inner + 2 * ssm.d_state]
    dt = y[..., -n_heads:]
    return z, xbc, dt, d_inner, n_heads


def _causal_conv(xbc: jnp.ndarray, conv_w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (B, L, C) with kernel (W, C)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(w):
        out = out + pad[:, i:i + xbc.shape[1], :].astype(jnp.float32) \
            * conv_w[i].astype(jnp.float32)
    return out.astype(xbc.dtype)


def _gated_norm(y, z, scale, eps=1e-6):
    g = y * jax.nn.silu(z)
    return layers.rms_normalize(g, eps) * scale


def mamba2_apply(params: Params, x: jnp.ndarray, ssm,
                 d_model: int) -> jnp.ndarray:
    """Chunkwise SSD, streamed: one ``lax.scan`` over chunks carrying the
    (H, P, N) state. Per-iteration intermediates are O(B * Q^2 * H) —
    constant in sequence length — which is what makes long_500k lowerable.
    x: (B, L, D_model)."""
    b, l, _ = x.shape
    q = min(ssm.chunk_size, l)
    assert l % q == 0, (l, q)
    nc = l // q

    y0 = x @ params["in_proj"]
    z, xbc, dt, d_inner, h = _split_zxbcdt(params, y0, d_model, ssm)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"]))
    xs = xbc[..., :d_inner].reshape(b, l, h, ssm.head_dim)
    bmat = xbc[..., d_inner:d_inner + ssm.d_state]          # (B, L, N)
    cmat = xbc[..., d_inner + ssm.d_state:]                 # (B, L, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    a = -jnp.exp(params["A_log"])                           # (H,)
    da = dt * a                                             # (B, L, H)

    # chunk-major reshapes: leading scan axis NC
    def chunked(t):
        return t.reshape(b, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xs_c = chunked(xs.astype(jnp.float32))                  # (NC,B,Q,H,P)
    b_c = chunked(bmat.astype(jnp.float32))                 # (NC,B,Q,N)
    c_c = chunked(cmat.astype(jnp.float32))                 # (NC,B,Q,N)
    dt_c = chunked(dt)                                      # (NC,B,Q,H)
    da_c = chunked(da)                                      # (NC,B,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(h_prev, inp):
        xb, bb, cb_, dtb, dab = inp
        da_cs = jnp.cumsum(dab, axis=1)                     # (B,Q,H)
        # intra-chunk: L[t,s] = exp(da_cs[t]-da_cs[s]) for s<=t
        diff = da_cs[:, :, None, :] - da_cs[:, None, :, :]  # (B,Q,Q,H)
        lmat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        cb_mat = jnp.einsum("bqn,bsn->bqs", cb_, bb)        # (B,Q,Q)
        att = cb_mat[..., None] * lmat * dtb[:, None, :, :]  # (B,Q,S,H)
        y_diag = jnp.einsum("bqsh,bshp->bqhp", att, xb)
        # contribution of carried state
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp",
                           cb_, h_prev, jnp.exp(da_cs))
        # state update to chunk end
        decay_to_end = jnp.exp(da_cs[:, -1:, :] - da_cs)    # (B,Q,H)
        s_c = jnp.einsum("bsh,bsn,bshp->bhpn",
                         dtb * decay_to_end, bb, xb)
        h_new = h_prev * jnp.exp(da_cs[:, -1, :])[:, :, None, None] + s_c
        return h_new, y_diag + y_off

    h0 = jnp.zeros((b, h, ssm.head_dim, ssm.d_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (xs_c, b_c, c_c, dt_c, da_c))
    y = ys.swapaxes(0, 1).reshape(b, l, h, ssm.head_dim)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, l, d_inner).astype(x.dtype)
    y = _gated_norm(y, z, params["norm"])
    return y @ params["out_proj"]


def mamba2_init_cache(batch: int, d_model: int, ssm,
                      dtype=layers.DTYPE) -> Params:
    d_inner = ssm.expand * d_model
    h = d_inner // ssm.head_dim
    conv_ch = d_inner + 2 * ssm.d_state
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, ssm.head_dim, ssm.d_state), jnp.float32),
    }


def mamba2_decode(params: Params, x: jnp.ndarray, cache: Params, ssm,
                  d_model: int) -> Tuple[jnp.ndarray, Params]:
    """Single-token recurrent step. x: (B, 1, D_model)."""
    b = x.shape[0]
    y0 = x @ params["in_proj"]
    z, xbc, dt, d_inner, h = _split_zxbcdt(params, y0, d_model, ssm)

    conv_in = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, W, C)
    conv_out = jnp.sum(conv_in.astype(jnp.float32)
                       * params["conv_w"].astype(jnp.float32)[None], axis=1,
                       keepdims=True)
    xbc = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = conv_in[:, 1:, :]

    xs = xbc[..., :d_inner].reshape(b, h, ssm.head_dim)
    bvec = xbc[:, 0, d_inner:d_inner + ssm.d_state].astype(jnp.float32)
    cvec = xbc[:, 0, d_inner + ssm.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a)                                  # (B, H)

    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt, bvec, xs.astype(jnp.float32))
    h_new = cache["ssm"] * decay[:, :, None, None] + dbx
    y = jnp.einsum("bn,bhpn->bhp", cvec, h_new)
    y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = _gated_norm(y, z, params["norm"])
    return y @ params["out_proj"], {"conv": new_conv, "ssm": h_new}


# ===========================================================================
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ===========================================================================

def mlstm_init(key, d_model: int, num_heads: int, xl) -> Tuple[Params, Axes]:
    d_inner = int(xl.mlstm_proj_factor * d_model)
    dh = d_inner // num_heads
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    params = {
        "up": layers.dense_init(k1, d_model, 2 * d_inner),
        "wq": layers.dense_init(k2, d_inner, num_heads, dh),
        "wk": layers.dense_init(k3, d_inner, num_heads, dh),
        "wv": layers.dense_init(k4, d_inner, num_heads, dh),
        "w_if": layers.dense_init(k5, d_inner, 2 * num_heads,
                                  dtype=jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((num_heads,)),
                                 3.0 * jnp.ones((num_heads,))]),
        "norm": jnp.ones((d_inner,), layers.DTYPE),
        "down": layers.dense_init(k6, d_inner, d_model),
    }
    axes = {
        "up": ("embed", "ff"), "wq": ("ff", "heads", None),
        "wk": ("ff", "heads", None), "wv": ("ff", "heads", None),
        "w_if": ("ff", None), "b_if": (None,), "norm": ("ff",),
        "down": ("ff", "embed"),
    }
    return params, axes


def _mlstm_gates(params, xi, num_heads):
    gates = xi.astype(jnp.float32) @ params["w_if"] + params["b_if"]
    li = gates[..., :num_heads]                          # input gate preact
    lf = jax.nn.log_sigmoid(gates[..., num_heads:])      # log forget gate
    return li, lf


def mlstm_apply(params: Params, x: jnp.ndarray, num_heads: int,
                xl) -> jnp.ndarray:
    """Chunkwise-parallel stabilized mLSTM. x: (B, L, D_model)."""
    b, l, d_model = x.shape
    up = x @ params["up"]
    d_inner = up.shape[-1] // 2
    xi, gate_br = up[..., :d_inner], up[..., d_inner:]
    q = jnp.einsum("bld,dhk->blhk", xi, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", xi, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", xi, params["wv"])
    li, lf = _mlstm_gates(params, xi, num_heads)         # (B, L, H)

    qc = min(xl.chunk_size, l)
    assert l % qc == 0
    nc = l // qc
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    def resh(t):
        return t.reshape(b, nc, qc, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    qs, ks, vs = resh(q), resh(k), resh(v)               # (NC,B,Q,H,dh)
    lis, lfs = resh(li), resh(lf)                        # (NC,B,Q,H)

    def chunk_step(carry, inp):
        cmat, nvec, m_prev = carry                       # (B,H,dk,dv),(B,H,dk),(B,H)
        qb, kb, vb, lib, lfb = inp
        f_cs = jnp.cumsum(lfb, axis=1)                   # (B,Q,H)
        # log weight of in-chunk source s for target t: F_t - F_s + i_s
        lw = (f_cs[:, :, None, :] - f_cs[:, None, :, :]
              + lib[:, None, :, :])                      # (B,T,S,H)
        tri = jnp.tril(jnp.ones((qc, qc), bool))
        lw = jnp.where(tri[None, :, :, None], lw, -jnp.inf)
        # carried-state log weight for target t
        lw_carry = m_prev[:, None, :] + f_cs             # (B,T,H)
        m_t = jnp.maximum(jnp.max(lw, axis=2), lw_carry)  # (B,T,H)
        m_t = jnp.maximum(m_t, -1e30)
        dmat = jnp.exp(lw - m_t[:, :, None, :])          # (B,T,S,H)
        scores = jnp.einsum("bthk,bshk->btsh",
                            qf := qb.astype(jnp.float32) * scale,
                            kb.astype(jnp.float32)) * dmat
        num_intra = jnp.einsum("btsh,bshv->bthv", scores, vb.astype(jnp.float32))
        den_intra = jnp.sum(scores, axis=2)              # (B,T,H)
        w_carry = jnp.exp(lw_carry - m_t)                # (B,T,H)
        num_inter = jnp.einsum("bthk,bhkv->bthv", qf, cmat) * w_carry[..., None]
        den_inter = jnp.einsum("bthk,bhk->bth", qf, nvec) * w_carry
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t)) + 1e-30
        h_out = (num_intra + num_inter) / den[..., None]  # (B,T,H,dv)

        # ---- state update to chunk end -------------------------------
        f_tot = f_cs[:, -1, :]                           # (B,H)
        lw_end = f_tot[:, None, :] - f_cs + lib          # (B,S,H)
        m_new = jnp.maximum(m_prev + f_tot, jnp.max(lw_end, axis=1))
        w_old = jnp.exp(m_prev + f_tot - m_new)          # (B,H)
        w_src = jnp.exp(lw_end - m_new[:, None, :])      # (B,S,H)
        kv = jnp.einsum("bsh,bshk,bshv->bhkv", w_src,
                        kb.astype(jnp.float32), vb.astype(jnp.float32))
        ksum = jnp.einsum("bsh,bshk->bhk", w_src, kb.astype(jnp.float32))
        c_new = cmat * w_old[:, :, None, None] + kv
        n_new = nvec * w_old[:, :, None] + ksum
        return (c_new, n_new, m_new), h_out

    c0 = jnp.zeros((b, num_heads, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, num_heads, dh), jnp.float32)
    m0 = jnp.full((b, num_heads), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk_step, (c0, n0, m0), (qs, ks, vs, lis, lfs))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, l, d_inner).astype(x.dtype)
    h = layers.rms_normalize(h) * params["norm"]
    h = h * jax.nn.silu(gate_br)
    return h @ params["down"]


def mlstm_init_cache(batch, d_model, num_heads, xl, dtype=jnp.float32):
    d_inner = int(xl.mlstm_proj_factor * d_model)
    dh = d_inner // num_heads
    return {
        "c": jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, num_heads, dh), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
    }


def mlstm_decode(params: Params, x: jnp.ndarray, cache: Params,
                 num_heads: int, xl) -> Tuple[jnp.ndarray, Params]:
    b = x.shape[0]
    up = x @ params["up"]
    d_inner = up.shape[-1] // 2
    xi, gate_br = up[..., :d_inner], up[..., d_inner:]
    q = jnp.einsum("bld,dhk->blhk", xi, params["wq"])[:, 0]
    k = jnp.einsum("bld,dhk->blhk", xi, params["wk"])[:, 0]
    v = jnp.einsum("bld,dhk->blhk", xi, params["wv"])[:, 0]
    li, lf = _mlstm_gates(params, xi[:, 0], num_heads)   # (B, H)
    dh = q.shape[-1]
    scale = 1.0 / math.sqrt(dh)

    m_new = jnp.maximum(lf + cache["m"], li)
    w_old = jnp.exp(lf + cache["m"] - m_new)
    w_in = jnp.exp(li - m_new)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c_new = cache["c"] * w_old[:, :, None, None] \
        + w_in[:, :, None, None] * kf[:, :, :, None] * vf[:, :, None, :]
    n_new = cache["n"] * w_old[:, :, None] + w_in[:, :, None] * kf
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhk,bhkv->bhv", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_new)),
                      jnp.exp(-m_new)) + 1e-30
    h = (num / den[..., None]).reshape(b, 1, d_inner).astype(x.dtype)
    h = layers.rms_normalize(h) * params["norm"]
    h = h * jax.nn.silu(gate_br)
    return h @ params["down"], {"c": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, num_heads: int, xl) -> Tuple[Params, Axes]:
    dh = d_model // num_heads
    d_ff = int(xl.slstm_proj_factor * d_model)
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_in": layers.dense_init(k1, d_model, 4 * d_model),
        "r": (jax.random.normal(k2, (num_heads, dh, 4 * dh), jnp.float32)
              / math.sqrt(dh)).astype(jnp.float32),
        "b": jnp.zeros((4 * d_model,), jnp.float32),
        "norm": jnp.ones((d_model,), layers.DTYPE),
    }
    axes = {
        "w_in": ("embed", "ff"), "r": ("heads", None, None), "b": (None,),
        "norm": (None,),
    }
    ffp, ffa = layers.mlp_init(k3, d_model, d_ff)
    params["ffn"], axes["ffn"] = ffp, ffa
    return params, axes


def _slstm_cell(params, pre, state, num_heads, dh):
    """pre: (B, 4*D) input preactivation; state: (h, c, n, m) each (B,H,dh|1)."""
    h_prev, c_prev, n_prev, m_prev = state
    b = pre.shape[0]
    rec = jnp.einsum("bhd,hdk->bhk", h_prev, params["r"])   # (B,H,4*dh)
    pre = pre.reshape(b, num_heads, 4 * dh) + rec
    z, i_t, f_t, o_t = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_t)
    # stabilized exponential gating (per head-channel)
    m_new = jnp.maximum(f_t + m_prev, i_t)
    i_g = jnp.exp(i_t - m_new)
    f_g = jnp.exp(f_t + m_prev - m_new)
    c_new = f_g * c_prev + i_g * z
    n_new = f_g * n_prev + i_g
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1e-6)
    return h_new, (h_new, c_new, n_new, m_new)


def slstm_apply(params: Params, x: jnp.ndarray, num_heads: int,
                xl) -> jnp.ndarray:
    b, l, d_model = x.shape
    dh = d_model // num_heads
    pre_all = (x @ params["w_in"]).astype(jnp.float32) + params["b"]

    def step(state, pre_t):
        h, state = _slstm_cell(params, pre_t, state, num_heads, dh)
        return state, h

    s0 = (jnp.zeros((b, num_heads, dh), jnp.float32),
          jnp.zeros((b, num_heads, dh), jnp.float32),
          jnp.zeros((b, num_heads, dh), jnp.float32),
          jnp.full((b, num_heads, dh), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, s0, pre_all.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(b, l, d_model).astype(x.dtype)
    h = layers.rms_normalize(h) * params["norm"]
    return h + layers.mlp_apply(params["ffn"], h)


def slstm_init_cache(batch, d_model, num_heads, dtype=jnp.float32):
    dh = d_model // num_heads
    z = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((batch, num_heads, dh), -1e30, jnp.float32)}


def slstm_decode(params: Params, x: jnp.ndarray, cache: Params,
                 num_heads: int, xl) -> Tuple[jnp.ndarray, Params]:
    b, _, d_model = x.shape
    dh = d_model // num_heads
    pre = (x[:, 0] @ params["w_in"]).astype(jnp.float32) + params["b"]
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, (h_n, c_n, n_n, m_n) = _slstm_cell(params, pre, state, num_heads, dh)
    h = h.reshape(b, 1, d_model).astype(x.dtype)
    h = layers.rms_normalize(h) * params["norm"]
    h = h + layers.mlp_apply(params["ffn"], h)
    return h, {"h": h_n, "c": c_n, "n": n_n, "m": m_n}
