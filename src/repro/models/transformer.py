"""Model assembly: stages of scanned layer stacks for every arch family.

A model is a sequence of *stages*; each stage is a homogeneous stack of
layers lowered as one ``jax.lax.scan`` over stacked parameters (constant
HLO size in depth). Heterogeneous patterns (Zamba2's shared attention
every 6th layer, Llama-Vision's cross-attn every 5th) become *super-block*
stages whose scan body contains an inner mini-scan.

Block interface (per layer):
  init(key)            -> (params, axes)
  apply(params, x, ctx)-> (x, aux)          full-sequence forward
  decode(params, x, cache, ctx) -> (x, cache)   one-token step
  init_cache(batch)    -> (cache, cache_axes)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe as moe_lib, ssm as ssm_lib
from repro.sharding.partition import constrain

Pytree = Any


@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    positions: Optional[jnp.ndarray] = None   # (B, S)
    memory: Optional[jnp.ndarray] = None      # (B, M, D) cross-attn memory
    pos: Any = None                           # scalar decode position
    causal: bool = True


def _remat(fn: Callable, mode: str) -> Callable:
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.nothing_saveable)


def stack_axes(axes: Pytree, prefix: Tuple = ("layers",)) -> Pytree:
    return jax.tree.map(lambda a: tuple(prefix) + tuple(a), axes,
                        is_leaf=lambda a: isinstance(a, tuple))


def stacked_init(init_fn: Callable, key, n: int) -> Tuple[Pytree, Pytree]:
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)
    return params, stack_axes(axes)


# ===========================================================================
# blocks
# ===========================================================================

class DenseBlock:
    """Pre-norm attention (GQA or MLA) + pre-norm FFN (dense or MoE)."""

    def __init__(self, cfg: ModelConfig, use_moe: bool = False,
                 d_ff: Optional[int] = None, causal: bool = True):
        self.cfg = cfg
        self.use_moe = use_moe and cfg.moe is not None
        self.d_ff = d_ff if d_ff is not None else cfg.d_ff
        self.causal = causal

    def init(self, key):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        if cfg.attn_type == "mla":
            attn_p, attn_a = attention.mla_init(k1, cfg.d_model,
                                                cfg.num_heads, cfg.mla)
        else:
            attn_p, attn_a = attention.gqa_init(
                k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim, cfg.qk_norm,
                pad_to_tp=cfg.pad_heads_to_tp)
        if self.use_moe:
            ffn_p, ffn_a = moe_lib.moe_init(k2, cfg.d_model, cfg.moe)
        else:
            ffn_p, ffn_a = layers.mlp_init(k2, cfg.d_model, self.d_ff)
        n1, a1 = layers.rmsnorm_init(cfg.d_model)
        n2, a2 = layers.rmsnorm_init(cfg.d_model)
        params = {"attn": attn_p, "ffn": ffn_p, "ln1": n1, "ln2": n2}
        axes = {"attn": attn_a, "ffn": ffn_a, "ln1": a1, "ln2": a2}
        return params, axes

    def apply(self, params, x, ctx: Ctx):
        cfg = self.cfg
        h = layers.rmsnorm(params["ln1"], x, cfg.norm_eps)
        # when q heads don't divide TP, attention is sharded on seq instead
        # ("attn_seq" maps to the model axis in that rule variant)
        h = constrain(h, ("batch", "attn_seq", None))
        if cfg.attn_type == "mla":
            a = attention.mla_apply(params["attn"], h, ctx.positions,
                                    cfg.rope_theta, cfg.mla)
        else:
            q_mask, _ = attention.ghost_masks(
                cfg.num_heads, cfg.num_kv_heads, cfg.pad_heads_to_tp)
            a = attention.gqa_apply(params["attn"], h, ctx.positions,
                                    cfg.rope_theta, cfg.qk_norm,
                                    causal=self.causal and ctx.causal,
                                    head_mask=q_mask)
        x = x + a
        x = constrain(x, ("batch", None, None))
        h = layers.rmsnorm(params["ln2"], x, cfg.norm_eps)
        if self.use_moe:
            f, aux = moe_lib.moe_apply(params["ffn"], h, cfg.moe)
        else:
            f, aux = layers.mlp_apply(params["ffn"], h), 0.0
        x = x + f
        x = constrain(x, ("batch", "res_seq", None))
        return x, aux

    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        if cfg.attn_type == "mla":
            c = attention.mla_init_cache(batch, max_seq, cfg.mla)
            a = {"c_kv": ("batch", "kv_seq", None),
                 "k_rope": ("batch", "kv_seq", None)}
        else:
            _, kv_mask = attention.ghost_masks(
                cfg.num_heads, cfg.num_kv_heads, cfg.pad_heads_to_tp)
            nkv = cfg.num_kv_heads if kv_mask is None else kv_mask.shape[0]
            quant = cfg.kv_cache_dtype == "int8"
            c = attention.gqa_init_cache(batch, max_seq, nkv,
                                         cfg.resolved_head_dim,
                                         quantized=quant)
            a = {"k": ("batch", "kv_seq", "kv", None),
                 "v": ("batch", "kv_seq", "kv", None)}
            if quant:
                a["k_scale"] = ("batch", "kv_seq", "kv")
                a["v_scale"] = ("batch", "kv_seq", "kv")
        return c, a

    def decode(self, params, x, cache, ctx: Ctx):
        cfg = self.cfg
        h = layers.rmsnorm(params["ln1"], x, cfg.norm_eps)
        if cfg.attn_type == "mla":
            a, cache = attention.mla_decode(params["attn"], h, cache, ctx.pos,
                                            cfg.rope_theta, cfg.mla)
        else:
            q_mask, _ = attention.ghost_masks(
                cfg.num_heads, cfg.num_kv_heads, cfg.pad_heads_to_tp)
            a, cache = attention.gqa_decode(params["attn"], h, cache, ctx.pos,
                                            cfg.rope_theta, cfg.qk_norm,
                                            head_mask=q_mask)
        x = x + a
        h = layers.rmsnorm(params["ln2"], x, cfg.norm_eps)
        if self.use_moe:
            f, _ = moe_lib.moe_apply(params["ffn"], h, cfg.moe)
        else:
            f = layers.mlp_apply(params["ffn"], h)
        return x + f, cache


class CrossBlock:
    """Gated cross-attention + FFN (Llama-Vision image layers / enc-dec)."""

    def __init__(self, cfg: ModelConfig, gated: bool = True):
        self.cfg = cfg
        self.gated = gated

    def init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        attn_p, attn_a = attention.cross_init(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim)
        ffn_p, ffn_a = layers.mlp_init(k2, cfg.d_model, cfg.d_ff or cfg.d_model * 4)
        n1, a1 = layers.rmsnorm_init(cfg.d_model)
        n2, a2 = layers.rmsnorm_init(cfg.d_model)
        params = {"attn": attn_p, "ffn": ffn_p, "ln1": n1, "ln2": n2}
        axes = {"attn": attn_a, "ffn": ffn_a, "ln1": a1, "ln2": a2}
        if self.gated:
            params["gate_attn"] = jnp.zeros((), jnp.float32)
            params["gate_ffn"] = jnp.zeros((), jnp.float32)
            axes["gate_attn"] = ()
            axes["gate_ffn"] = ()
        return params, axes

    def apply(self, params, x, ctx: Ctx):
        cfg = self.cfg
        h = layers.rmsnorm(params["ln1"], x, cfg.norm_eps)
        a = attention.cross_apply(params["attn"], h, ctx.memory)
        if self.gated:
            a = jnp.tanh(params["gate_attn"]).astype(a.dtype) * a
        x = x + a
        h = layers.rmsnorm(params["ln2"], x, cfg.norm_eps)
        f = layers.mlp_apply(params["ffn"], h)
        if self.gated:
            f = jnp.tanh(params["gate_ffn"]).astype(f.dtype) * f
        return x + f, 0.0

    def init_cache(self, batch: int, max_seq: int):
        # cross-attn KV depends only on the (fixed) memory; nothing cached —
        # recomputed per step from ctx.memory (cheap: memory is short).
        return {}, {}

    def decode(self, params, x, cache, ctx: Ctx):
        y, _ = self.apply(params, x, ctx)
        return y, cache


class MambaBlock:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        p, a = ssm_lib.mamba2_init(key, cfg.d_model, cfg.ssm)
        n, na = layers.rmsnorm_init(cfg.d_model)
        return {"mamba": p, "ln": n}, {"mamba": a, "ln": na}

    def apply(self, params, x, ctx: Ctx):
        h = layers.rmsnorm(params["ln"], x, self.cfg.norm_eps)
        y = ssm_lib.mamba2_apply(params["mamba"], h, self.cfg.ssm,
                                 self.cfg.d_model)
        x = x + y
        return constrain(x, ("batch", None, None)), 0.0

    def init_cache(self, batch: int, max_seq: int):
        c = ssm_lib.mamba2_init_cache(batch, self.cfg.d_model, self.cfg.ssm)
        a = {"conv": ("batch", None, "ff"), "ssm": ("batch", "heads", None, None)}
        return c, a

    def decode(self, params, x, cache, ctx: Ctx):
        h = layers.rmsnorm(params["ln"], x, self.cfg.norm_eps)
        y, cache = ssm_lib.mamba2_decode(params["mamba"], h, cache,
                                         self.cfg.ssm, self.cfg.d_model)
        return x + y, cache


class MLSTMBlock:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        p, a = ssm_lib.mlstm_init(key, self.cfg.d_model, self.cfg.num_heads,
                                  self.cfg.xlstm)
        n, na = layers.rmsnorm_init(self.cfg.d_model)
        return {"mlstm": p, "ln": n}, {"mlstm": a, "ln": na}

    def apply(self, params, x, ctx: Ctx):
        h = layers.rmsnorm(params["ln"], x, self.cfg.norm_eps)
        y = ssm_lib.mlstm_apply(params["mlstm"], h, self.cfg.num_heads,
                                self.cfg.xlstm)
        return x + y, 0.0

    def init_cache(self, batch: int, max_seq: int):
        c = ssm_lib.mlstm_init_cache(batch, self.cfg.d_model,
                                     self.cfg.num_heads, self.cfg.xlstm)
        a = {"c": ("batch", "heads", None, None),
             "n": ("batch", "heads", None), "m": ("batch", "heads")}
        return c, a

    def decode(self, params, x, cache, ctx: Ctx):
        h = layers.rmsnorm(params["ln"], x, self.cfg.norm_eps)
        y, cache = ssm_lib.mlstm_decode(params["mlstm"], h, cache,
                                        self.cfg.num_heads, self.cfg.xlstm)
        return x + y, cache


class SLSTMBlock:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        p, a = ssm_lib.slstm_init(key, self.cfg.d_model, self.cfg.num_heads,
                                  self.cfg.xlstm)
        n, na = layers.rmsnorm_init(self.cfg.d_model)
        return {"slstm": p, "ln": n}, {"slstm": a, "ln": na}

    def apply(self, params, x, ctx: Ctx):
        h = layers.rmsnorm(params["ln"], x, self.cfg.norm_eps)
        y = ssm_lib.slstm_apply(params["slstm"], h, self.cfg.num_heads,
                                self.cfg.xlstm)
        return x + y, 0.0

    def init_cache(self, batch: int, max_seq: int):
        c = ssm_lib.slstm_init_cache(batch, self.cfg.d_model,
                                     self.cfg.num_heads)
        a = {k: ("batch", "heads", None) for k in ("h", "c", "n", "m")}
        return c, a

    def decode(self, params, x, cache, ctx: Ctx):
        h = layers.rmsnorm(params["ln"], x, self.cfg.norm_eps)
        y, cache = ssm_lib.slstm_decode(params["slstm"], h, cache,
                                        self.cfg.num_heads, self.cfg.xlstm)
        return x + y, cache


class EncDecBlock:
    """Decoder layer with self-attn + cross-attn + FFN (seamless decoder)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.self_block = DenseBlock(cfg)
        self.cross = CrossBlock(cfg, gated=False)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        sp, sa = self.self_block.init(k1)
        cp, ca = self.cross.init(k2)
        return {"self": sp, "cross": cp}, {"self": sa, "cross": ca}

    def apply(self, params, x, ctx: Ctx):
        x, _ = self.self_block.apply(params["self"], x, ctx)
        x, _ = self.cross.apply(params["cross"], x, ctx)
        return x, 0.0

    def init_cache(self, batch: int, max_seq: int):
        c, a = self.self_block.init_cache(batch, max_seq)
        return c, a

    def decode(self, params, x, cache, ctx: Ctx):
        x, cache = self.self_block.decode(params["self"], x, cache, ctx)
        x, _ = self.cross.decode(params["cross"], x, {}, ctx)
        return x, cache


# ===========================================================================
# stages
# ===========================================================================

@dataclasses.dataclass
class Stage:
    """A scanned stack of ``n`` identical blocks (or super-blocks)."""
    name: str
    blocks: List[Any]          # block templates inside one super-block
    n: int                     # scan length
    shared: Tuple[int, ...] = ()   # indices of blocks whose params are shared

    def init(self, key):
        keys = jax.random.split(key, len(self.blocks) + 1)
        scanned_p, scanned_a, shared_p, shared_a = {}, {}, {}, {}
        for i, blk in enumerate(self.blocks):
            bname = f"b{i}"
            if i in self.shared:
                p, a = blk.init(keys[i])
                shared_p[bname], shared_a[bname] = p, a
            else:
                p, a = stacked_init(blk.init, keys[i], self.n)
                scanned_p[bname], scanned_a[bname] = p, a
        return ({"scanned": scanned_p, "shared": shared_p},
                {"scanned": scanned_a, "shared": shared_a})

    def apply(self, params, x, ctx: Ctx, remat: str):
        def body(carry, layer_params):
            h, aux = carry
            for i, blk in enumerate(self.blocks):
                bname = f"b{i}"
                p = (params["shared"][bname] if i in self.shared
                     else layer_params[bname])
                h, a = blk.apply(p, h, ctx)
                aux = aux + a
            return (h, aux), None

        body = _remat(body, remat)
        aux0 = jnp.zeros((), jnp.float32)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["scanned"])
        return x, aux

    def init_cache(self, batch: int, max_seq: int):
        caches, axes = {}, {}
        for i, blk in enumerate(self.blocks):
            bname = f"b{i}"
            c, a = blk.init_cache(batch, max_seq)
            if not c:
                caches[bname], axes[bname] = {}, {}
                continue
            caches[bname] = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (self.n,) + t.shape), c)
            axes[bname] = stack_axes(a)
        return caches, axes

    def decode(self, params, x, cache, ctx: Ctx):
        def body(h, inp):
            layer_params, layer_cache = inp
            new_cache = {}
            for i, blk in enumerate(self.blocks):
                bname = f"b{i}"
                p = (params["shared"][bname] if i in self.shared
                     else layer_params[bname])
                h, c = blk.decode(p, h, layer_cache[bname], ctx)
                new_cache[bname] = c
            return h, new_cache

        x, new_cache = jax.lax.scan(body, x, (params["scanned"], cache))
        return x, new_cache


# ===========================================================================
# stage layout per architecture family
# ===========================================================================

def build_stages(cfg: ModelConfig) -> List[Stage]:
    if cfg.family == "moe":
        m = cfg.moe
        stages = []
        if m.first_dense_layers:
            stages.append(Stage("dense", [DenseBlock(cfg, use_moe=False,
                                                     d_ff=m.dense_d_ff)],
                                m.first_dense_layers))
        stages.append(Stage("moe", [DenseBlock(cfg, use_moe=True)],
                            cfg.num_layers - m.first_dense_layers))
        return stages

    if cfg.family == "vlm":
        v = cfg.vision
        per = v.cross_attn_every
        n_super = cfg.num_layers // per
        blocks = [DenseBlock(cfg) for _ in range(per - 1)] + [CrossBlock(cfg)]
        return [Stage("vlm_super", blocks, n_super)]

    if cfg.family == "hybrid":
        s = cfg.ssm
        per = s.attn_every
        n_super = cfg.num_layers // per
        trailing = cfg.num_layers - n_super * per
        blocks = [MambaBlock(cfg) for _ in range(per - 1)] + [DenseBlock(cfg)]
        shared = (per - 1,) if s.shared_attn else ()
        stages = [Stage("zamba_super", blocks, n_super, shared=shared)]
        if trailing:
            stages.append(Stage("mamba_tail", [MambaBlock(cfg)], trailing))
        return stages

    if cfg.family == "ssm":   # xLSTM: alternating (mLSTM, sLSTM)
        n_super = cfg.num_layers // 2
        return [Stage("xlstm_super", [MLSTMBlock(cfg), SLSTMBlock(cfg)],
                      n_super)]

    if cfg.family == "audio":  # encoder-decoder
        enc_cfg = cfg
        return [Stage("encoder", [DenseBlock(enc_cfg, causal=False)],
                      cfg.encdec.encoder_layers),
                Stage("decoder", [EncDecBlock(cfg)], cfg.num_layers)]

    # dense
    return [Stage("dense", [DenseBlock(cfg)], cfg.num_layers)]
