"""Attention variants: GQA (flash-style chunked), MLA (latent KV), cross-attn.

Full-sequence paths use a chunked online-softmax ("flash") formulation in
pure jnp so that 32k-token prefill never materializes an (S, S) score
matrix: the outer dimension is scanned in KV chunks with fp32 running
(max, sum, acc) statistics. Decode paths read a dense KV cache.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Axes, Params

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA projection params
# ---------------------------------------------------------------------------

def ghost_masks(num_heads: int, num_kv_heads: int, pad_to_tp: int):
    """(q_mask (q',), kv_mask (kv',)) bool for the padded layout, or
    (None, None) when no padding applies."""
    from repro.configs.base import ghost_head_layout
    if not pad_to_tp or num_heads % pad_to_tp == 0:
        return None, None
    qp, kvp, repp = ghost_head_layout(num_heads, num_kv_heads, pad_to_tp)
    rep = num_heads // num_kv_heads
    idx = jnp.arange(qp)
    g, r = idx // repp, idx % repp
    q_mask = (g < num_kv_heads) & (r < rep)
    kv_mask = jnp.arange(kvp) < num_kv_heads
    return q_mask, kv_mask


def gqa_init(key, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, qk_norm: bool,
             pad_to_tp: int = 0) -> Tuple[Params, Axes]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    q_mask, kv_mask = ghost_masks(num_heads, num_kv_heads, pad_to_tp)
    nh, nkv = num_heads, num_kv_heads
    if q_mask is not None:
        nh, nkv = q_mask.shape[0], kv_mask.shape[0]
    params = {
        "wq": layers.dense_init(k1, d_model, nh, head_dim),
        "wk": layers.dense_init(k2, d_model, nkv, head_dim),
        "wv": layers.dense_init(k3, d_model, nkv, head_dim),
        "wo": layers.dense_init(k4, nh * head_dim, d_model,
                                scale=1.0 / math.sqrt(nh * head_dim)),
    }
    if q_mask is not None:
        # structurally-zero ghost heads: zero q/k/v columns and wo rows;
        # the output mask keeps their gradients exactly zero forever
        params["wq"] = params["wq"] * q_mask[None, :, None].astype(
            params["wq"].dtype)
        params["wk"] = params["wk"] * kv_mask[None, :, None].astype(
            params["wk"].dtype)
        params["wv"] = params["wv"] * kv_mask[None, :, None].astype(
            params["wv"].dtype)
        wo_mask = jnp.repeat(q_mask, head_dim)
        params["wo"] = params["wo"] * wo_mask[:, None].astype(
            params["wo"].dtype)
    axes = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv", None),
        "wv": ("embed", "kv", None),
        "wo": ("heads_flat", "embed"),
    }
    if qk_norm:
        params["q_norm"] = jnp.ones((head_dim,), layers.DTYPE)
        params["k_norm"] = jnp.ones((head_dim,), layers.DTYPE)
        axes["q_norm"] = (None,)
        axes["k_norm"] = (None,)
    return params, axes


def _project_qkv(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 rope_theta: float, qk_norm: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if qk_norm:
        q = layers.rms_normalize(q) * params["q_norm"]
        k = layers.rms_normalize(k) * params["k_norm"]
    q = layers.apply_rope(q, positions, rope_theta)
    k = layers.apply_rope(k, positions, rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked online-softmax attention (full sequence)
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool, chunk_k: int = 512,
                    q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D). Returns (B, Sq, H, D).

    Scans KV in chunks with fp32 running softmax stats. GQA is handled by
    broadcasting KV heads up to H *inside* the chunk loop (a (B, chunk, H,
    D) tile) rather than reshaping H -> (KV, rep): the reshape would split
    the TP-sharded head dim and force GSPMD to replicate the (B, S, H,
    chunk) score tensor on every device — measured 150 GiB/device on the
    qwen3 train cell before this fix. ``q_offset`` is the absolute position
    of q[0] (used when the query block is a suffix of the KV sequence).
    """
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    chunk_k = min(chunk_k, sk)
    pad = (-sk) % chunk_k
    if pad:   # pad KV to a chunk multiple; padded keys masked below
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sk_p = sk + pad
    n_chunks = sk_p // chunk_k
    scale = 1.0 / math.sqrt(d)

    qf = q * scale
    k_chunks = k.reshape(b, n_chunks, chunk_k, kv, d).transpose(1, 0, 2, 3, 4)
    v_chunks = v.reshape(b, n_chunks, chunk_k, kv, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        idx, kc, vc = inputs
        if rep > 1:   # broadcast KV heads to H (keeps head dim TP-sharded)
            kc = jnp.repeat(kc, rep, axis=2)
            vc = jnp.repeat(vc, rep, axis=2)
        # scores: (B, Sq, H, chunk)
        s = jnp.einsum("bqhd,bchd->bqhc", qf, kc).astype(jnp.float32)
        k_pos = idx * chunk_k + jnp.arange(chunk_k)
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]          # (Sq, chunk)
            s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        elif pad:
            s = jnp.where((k_pos < sk)[None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhc,bchd->bqhd",
                        p.astype(v.dtype), vc).astype(jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    a0 = jnp.zeros((b, sq, h, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), k_chunks, v_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def gqa_apply(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
              rope_theta: float, qk_norm: bool = False,
              chunk_k: int = 1024, causal: bool = True,
              head_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence self attention. x: (B, S, D_model)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, positions, rope_theta, qk_norm)
    out = flash_attention(q, k, v, causal=causal, chunk_k=min(chunk_k, s))
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    return out.reshape(b, s, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

def gqa_init_cache(batch: int, max_seq: int, num_kv_heads: int,
                   head_dim: int, dtype=layers.DTYPE,
                   quantized: bool = False) -> Params:
    if quantized:
        # int8 storage + per-(batch, pos, head) scales: 2x fewer cache
        # bytes per decode step (the decode roofline is cache-read-bound)
        return {
            "k": jnp.zeros((batch, max_seq, num_kv_heads, head_dim),
                           jnp.int8),
            "v": jnp.zeros((batch, max_seq, num_kv_heads, head_dim),
                           jnp.int8),
            "k_scale": jnp.zeros((batch, max_seq, num_kv_heads),
                                 jnp.bfloat16),
            "v_scale": jnp.zeros((batch, max_seq, num_kv_heads),
                                 jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, max_seq, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, num_kv_heads, head_dim), dtype),
    }


def _quantize_kv(x: jnp.ndarray):
    """x: (B, 1, KV, D) -> (int8, scale (B, 1, KV))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def gqa_decode(params: Params, x: jnp.ndarray, cache: Params,
               pos: jnp.ndarray, rope_theta: float,
               qk_norm: bool = False,
               head_mask: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. x: (B, 1, D); pos: scalar int32 (current index).

    Attends over cache[0:pos] plus the new token; cache is dense
    (B, S_max, KV, D) and masked by position — FLOPs/bytes reflect a full
    seq_len-deep cache, per the assignment's decode_* semantics.
    """
    b, _, _ = x.shape
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, positions, rope_theta, qk_norm)
    quantized = "k_scale" in cache
    new_cache = {}
    if quantized:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], kq,
                                               (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], vq,
                                               (0, pos, 0, 0))
        ks_c = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                            (0, pos, 0))
        vs_c = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                            (0, pos, 0))
        new_cache = {"k": k_cache, "v": v_cache, "k_scale": ks_c,
                     "v_scale": vs_c}
        k_eff = k_cache.astype(jnp.bfloat16) * ks_c[..., None]
        v_eff = v_cache.astype(jnp.bfloat16) * vs_c[..., None]
    else:
        k_eff = k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new, (0, pos, 0, 0))
        v_eff = v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new, (0, pos, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}

    h, kv = q.shape[2], k_eff.shape[2]
    rep = h // kv
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    qf = (q * scale).reshape(b, kv, rep, d)
    s = jnp.einsum("bgrd,bcgd->bgrc", qf, k_eff).astype(jnp.float32)
    valid = jnp.arange(k_eff.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrc,bcgd->bgrd", p, v_eff)
    if head_mask is not None:
        out = out * head_mask.reshape(1, kv, rep, 1).astype(out.dtype)
    out = out.reshape(b, 1, h * d) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, d_model: int, num_heads: int, mla) -> Tuple[Params, Axes]:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    nope, rope_d, v_d = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    params = {
        "wq_a": layers.dense_init(k1, d_model, mla.q_lora_rank),
        "q_norm": jnp.ones((mla.q_lora_rank,), layers.DTYPE),
        "wq_b": layers.dense_init(k2, mla.q_lora_rank, num_heads, nope + rope_d),
        "wkv_a": layers.dense_init(k3, d_model, mla.kv_lora_rank + rope_d),
        "kv_norm": jnp.ones((mla.kv_lora_rank,), layers.DTYPE),
        "wkv_b_k": layers.dense_init(k4, mla.kv_lora_rank, num_heads, nope),
        "wkv_b_v": layers.dense_init(k4, mla.kv_lora_rank, num_heads, v_d),
        "wo": layers.dense_init(k5, num_heads * v_d, d_model,
                                scale=1.0 / math.sqrt(num_heads * v_d)),
    }
    axes = {
        "wq_a": ("embed", None),
        "q_norm": (None,),
        "wq_b": (None, "heads", None),
        "wkv_a": ("embed", None),
        "kv_norm": (None,),
        "wkv_b_k": (None, "heads", None),
        "wkv_b_v": (None, "heads", None),
        "wo": ("heads_flat", "embed"),
    }
    return params, axes


def _mla_qkr(params: Params, x: jnp.ndarray, positions, rope_theta, mla):
    nope, rope_d = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    cq = layers.rms_normalize(x @ params["wq_a"]) * params["q_norm"]
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = layers.apply_rope(q_rope, positions, rope_theta)
    ckv_full = x @ params["wkv_a"]
    c_kv = layers.rms_normalize(ckv_full[..., :mla.kv_lora_rank]) * params["kv_norm"]
    k_rope = ckv_full[..., mla.kv_lora_rank:][:, :, None, :]     # 1 shared head
    k_rope = layers.apply_rope(k_rope, positions, rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
              rope_theta: float, mla, chunk_k: int = 1024) -> jnp.ndarray:
    """Full-sequence MLA (naive/un-absorbed form for train & prefill)."""
    b, s, _ = x.shape
    nope, rope_d, v_d = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(params, x, positions, rope_theta, mla)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b_k"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wkv_b_v"])
    h = k_nope.shape[2]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rope_d))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v to qk dim for the shared flash kernel, then slice back
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rope_d - v_d)))
    out = flash_attention(q_full, k_full, v_pad, causal=True,
                          chunk_k=min(chunk_k, s))[..., :v_d]
    return out.reshape(b, s, -1) @ params["wo"]


def mla_init_cache(batch: int, max_seq: int, mla, dtype=layers.DTYPE) -> Params:
    return {
        "c_kv": jnp.zeros((batch, max_seq, mla.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, mla.qk_rope_head_dim), dtype),
    }


def mla_decode(params: Params, x: jnp.ndarray, cache: Params, pos,
               rope_theta: float, mla) -> Tuple[jnp.ndarray, Params]:
    """Absorbed-form MLA decode: the cache holds only the latent c_kv and
    the shared rope key — DeepSeek-V3's KV-cache compression."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkr(
        params, x, positions, rope_theta, mla)
    c_cache = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new, (0, pos, 0))
    r_cache = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, pos, 0))

    # absorb q_nope through wkv_b_k into latent space: (B, H, kv_lora)
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], params["wkv_b_k"])
    scale = 1.0 / math.sqrt(mla.qk_nope_head_dim + mla.qk_rope_head_dim)
    s = (jnp.einsum("bhr,bsr->bhs", q_abs, c_cache)
         + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], r_cache)).astype(jnp.float32)
    s = s * scale
    valid = jnp.arange(c_cache.shape[1]) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhs,bsr->bhr", p, c_cache)             # latent context
    out = jnp.einsum("bhr,rhk->bhk", ctx, params["wkv_b_v"])  # (B, H, v_d)
    out = out.reshape(b, 1, -1) @ params["wo"]
    return out, {"c_kv": c_cache, "k_rope": r_cache}


# ---------------------------------------------------------------------------
# cross attention (vision / enc-dec memory)
# ---------------------------------------------------------------------------

def cross_init(key, d_model: int, num_heads: int, num_kv_heads: int,
               head_dim: int) -> Tuple[Params, Axes]:
    params, axes = gqa_init(key, d_model, num_heads, num_kv_heads, head_dim,
                            qk_norm=False)
    return params, axes


def cross_apply(params: Params, x: jnp.ndarray, memory: jnp.ndarray,
                chunk_k: int = 1024) -> jnp.ndarray:
    """x: (B, S, D); memory: (B, M, D) (patch/frame embeddings or encoder out)."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bmd,dhk->bmhk", memory, params["wk"])
    v = jnp.einsum("bmd,dhk->bmhk", memory, params["wv"])
    out = flash_attention(q, k, v, causal=False,
                          chunk_k=min(chunk_k, memory.shape[1]))
    return out.reshape(b, s, -1) @ params["wo"]
