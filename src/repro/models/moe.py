"""Dropless grouped MoE (MaxText-style dense dispatch, no token dropping).

Tokens are reshaped into groups of ``group_size``; per group a
(S, E, C) dispatch/combine pair routes top-k tokens into per-expert
buffer slots. The dispatch einsums keep the expert dim (logical axis
"experts" -> mesh "model") and the group dim (logical "batch" -> mesh
"data") sharded, which is EP x DP under GSPMD. Shared experts are a plain
SwiGLU applied to every token (DeepSeek fine-grained design).

Routing is dropless (DeepSeek-V3 style): every top-k assignment gets a
slot.  Capacity-based dropping would silently make decode diverge from
prefill — which tokens survive depends on the group they share, and a
decode step's group is just that step's tokens.

Dropless dense dispatch sizes the slot buffers at group_size (the worst
case), which inflates the (G, S, E, C) tensors by ~E/top_k over a
capacity-factor buffer at full production configs.  At that scale the
dense (S, E, C) formulation itself is the wrong tool — a sorted /
grouped-GEMM dispatch (MegaBlocks-style) is the production path; the
dense form here favors correctness and GSPMD-sharding clarity at the
reduced scales this repo executes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Axes, Params
from repro.sharding.partition import constrain


def _capacity(group_size: int, top_k: int, num_experts: int,
              capacity_factor: float) -> int:
    # dropless bound: top-k indices are distinct, so a group can send at
    # most one assignment per token to any one expert — group_size slots
    # always suffice, and nothing is ever cut by the ``pos < c`` gate
    del top_k, num_experts, capacity_factor
    return max(8, ((group_size + 7) // 8) * 8)


def moe_init(key, d_model: int, moe) -> Tuple[Params, Axes]:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, f = moe.num_experts, moe.expert_d_ff
    params = {
        "router": layers.dense_init(k1, d_model, e, dtype=jnp.float32),
        "w_gate": jax.vmap(lambda k: layers.dense_init(k, d_model, f))(
            jax.random.split(k2, e)),
        "w_up": jax.vmap(lambda k: layers.dense_init(k, d_model, f))(
            jax.random.split(k3, e)),
        "w_down": jax.vmap(lambda k: layers.dense_init(k, f, d_model))(
            jax.random.split(k4, e)),
    }
    axes = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ff"),
        "w_up": ("experts", "embed", "ff"),
        "w_down": ("experts", "ff", "embed"),
    }
    if moe.num_shared_experts:
        shared_ff = moe.expert_d_ff * moe.num_shared_experts
        p, a = layers.mlp_init(k5, d_model, shared_ff)
        params["shared"], axes["shared"] = p, a
    return params, axes


def moe_apply(params: Params, x: jnp.ndarray, moe) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, M) -> (y, aux_loss)."""
    b, s, m = x.shape
    e, k = moe.num_experts, moe.top_k
    g_size = min(moe.group_size, b * s)
    n_groups = (b * s) // g_size
    assert b * s % g_size == 0, (b, s, g_size)
    c = _capacity(g_size, k, e, moe.capacity_factor)

    xg = x.reshape(n_groups, g_size, m)
    xg = constrain(xg, ("batch", None, None))

    logits = (xg.astype(jnp.float32) @ params["router"])      # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (G, S, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # position of each assignment within its expert's capacity buffer:
    # flatten (S, k) into a priority order, cumsum per expert.
    mask = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)   # (G, S, k, E)
    flat = mask.reshape(n_groups, g_size * k, e)
    pos_flat = jnp.cumsum(flat, axis=1) - flat                # (G, S*k, E)
    pos = pos_flat.reshape(n_groups, g_size, k, e)
    keep = mask * (pos < c)
    # combine: (G, S, E, C) weighted by gate value
    pos_oh = jax.nn.one_hot(jnp.sum(pos * mask, axis=-1), c,
                            dtype=jnp.float32)                # (G, S, k, C)
    combine = jnp.einsum("gske,gsk,gskc->gsec",
                         keep, gate_vals, pos_oh)
    dispatch = (combine > 0).astype(x.dtype)
    combine = combine.astype(x.dtype)
    combine = constrain(combine, ("batch", None, "experts", None))
    dispatch = constrain(dispatch, ("batch", None, "experts", None))

    # route: (G, E, C, M)
    expert_in = jnp.einsum("gsec,gsm->gecm", dispatch, xg)
    expert_in = constrain(expert_in, ("batch", "experts", None, None))
    h = (jax.nn.silu(jnp.einsum("gecm,emf->gecf", expert_in, params["w_gate"]))
         * jnp.einsum("gecm,emf->gecf", expert_in, params["w_up"]))
    expert_out = jnp.einsum("gecf,efm->gecm", h, params["w_down"])
    expert_out = constrain(expert_out, ("batch", "experts", None, None))
    y = jnp.einsum("gsec,gecm->gsm", combine, expert_out)

    if "shared" in params:
        y = y + layers.mlp_apply(params["shared"], xg)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    frac = jnp.mean(jnp.sum(keep, axis=2), axis=(0, 1))       # (E,) dispatch frac
    prob = jnp.mean(probs, axis=(0, 1))                       # (E,)
    aux = e * jnp.sum(frac * prob) * moe.aux_loss_weight

    return y.reshape(b, s, m), aux
