"""Shared building blocks for the architecture zoo.

Parameters are plain dict pytrees. Every initializer returns two parallel
trees: ``params`` (arrays) and ``axes`` (tuples of logical axis names per
array dim) — ``repro.sharding.partition`` maps logical axes onto mesh axes.

Logical axis vocabulary:
  "embed"   – model width dim of big matrices (FSDP-sharded on data)
  "vocab"   – vocabulary dim (TP-sharded on model)
  "heads"   – attention-head dim (TP-sharded on model)
  "kv"      – kv-head dim (TP-sharded on model)
  "ff"      – FFN hidden dim (TP-sharded on model)
  "experts" – MoE expert dim (EP-sharded on model)
  None      – replicated dim (norm scales, small vectors, head_dim, state)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
Axes = Dict[str, Any]

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# param helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, *out_dims: int, scale: Optional[float] = None,
               dtype=DTYPE) -> jnp.ndarray:
    """Truncated-normal fan-in init for a (in_dim, *out_dims) weight."""
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    shape = (in_dim, *out_dims)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=DTYPE) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int) -> Tuple[Params, Axes]:
    return {"scale": jnp.ones((dim,), DTYPE)}, {"scale": (None,)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def rms_normalize(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Scale-free RMS normalization (used by qk_norm with its own scale)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int) -> Tuple[Params, Axes]:
    k1, k2, k3 = jax.random.split(key, 3)
    params = {
        "w_gate": dense_init(k1, d_model, d_ff),
        "w_up": dense_init(k2, d_model, d_ff),
        "w_down": dense_init(k3, d_ff, d_model),
    }
    axes = {
        "w_gate": ("embed", "ff"),
        "w_up": ("embed", "ff"),
        "w_down": ("ff", "embed"),
    }
    return params, axes


def mlp_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ params["w_gate"])
    up = x @ params["w_up"]
    return (gate * up) @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def pad_vocab(vocab: int, mult: int = 128) -> int:
    """Pad the vocab dim to a TP/MXU-friendly multiple. Logical ids beyond
    ``vocab`` are masked in the loss and at decode argmax; without padding
    an indivisible vocab (seamless: 256206) leaves the logits unsharded —
    measured 33 GiB/device on the prefill_32k cell."""
    return ((vocab + mult - 1) // mult) * mult


def embedding_init(key, vocab: int, d_model: int, tie: bool) -> Tuple[Params, Axes]:
    k1, k2 = jax.random.split(key)
    vp = pad_vocab(vocab)
    params = {"table": embed_init(k1, vp, d_model)}
    axes = {"table": ("vocab", "embed")}
    if not tie:
        params["unembed"] = dense_init(k2, d_model, vp)
        axes["unembed"] = ("embed", "vocab")
    return params, axes


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "unembed" in params:
        return x @ params["unembed"]
    return x @ params["table"].T


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 vocab_size: int = 0) -> jnp.ndarray:
    """Mean cross-entropy; logits (..., vocab_padded) fp32-accumulated.

    The gold logit is picked with an iota-compare masked sum rather than
    take_along_axis: a gather along a vocab-sharded dim would make GSPMD
    all-gather the full logits; the masked sum stays sharded (partial sums
    + one small all-reduce). ``vocab_size``: logical vocab — padded tail
    ids are excluded from the logsumexp.
    """
    logits = logits.astype(jnp.float32)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    if vocab_size and vocab_size < logits.shape[-1]:
        logits = jnp.where(vocab_iota < vocab_size, logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = (vocab_iota == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)
