"""Public model API: init / forward / loss / cache / decode for every arch.

All functions are pure and jit-able. Parameter and cache pytrees carry a
parallel *axes* pytree of logical axis names consumed by
``repro.sharding.partition``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.transformer import Ctx, build_stages
from repro.models.transformer import DenseBlock
from repro.sharding.partition import constrain

Pytree = Any

EMBED_HEAD_DIM = 128   # ARCADE embedding dimensionality (paper §7.1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Tuple[Pytree, Pytree]:
    stages = build_stages(cfg)
    keys = jax.random.split(key, len(stages) + 3)
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    ep, ea = layers.embedding_init(keys[0], cfg.vocab_size, cfg.d_model,
                                   cfg.tie_embeddings)
    params["embedding"], axes["embedding"] = ep, ea
    fp, fa = layers.rmsnorm_init(cfg.d_model)
    params["final_norm"], axes["final_norm"] = fp, fa
    for i, st in enumerate(stages):
        p, a = st.init(keys[i + 1])
        params[st.name], axes[st.name] = p, a
    if cfg.mtp_depth:
        k = keys[-2]
        blk = DenseBlock(cfg, use_moe=False,
                         d_ff=cfg.moe.dense_d_ff if cfg.moe else cfg.d_ff)
        bp, ba = blk.init(k)
        params["mtp"] = {
            "proj": layers.dense_init(k, 2 * cfg.d_model, cfg.d_model),
            "block": bp,
        }
        axes["mtp"] = {"proj": ("embed", "embed"), "block": ba}
    if cfg.name.startswith("arcade-embedder"):
        params["embed_head"] = layers.dense_init(keys[-1], cfg.d_model,
                                                 EMBED_HEAD_DIM)
        axes["embed_head"] = ("embed", None)
    return params, axes


def param_axes(cfg: ModelConfig) -> Pytree:
    """Axes pytree without materializing parameters.

    The axes tree is static Python structure; capture it by side effect
    while abstractly evaluating the initializer (no allocation).
    """
    box = {}

    def f(k):
        p, a = init_params(k, cfg)
        box["axes"] = a
        return p

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["axes"]


def param_shapes(cfg: ModelConfig) -> Pytree:
    return jax.eval_shape(lambda k: init_params(k, cfg)[0],
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward trunk
# ---------------------------------------------------------------------------

def _decoder_stages(cfg, stages):
    return [s for s in stages if s.name != "encoder"]


def _run_encoder(params, cfg, stages, memory):
    """Audio family: run the (non-causal) encoder over frontend embeddings."""
    enc = [s for s in stages if s.name == "encoder"]
    if not enc or memory is None:
        return memory
    b, m, _ = memory.shape
    pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None], (b, m))
    ctx = Ctx(cfg=cfg, positions=pos, causal=False)
    h, _ = enc[0].apply(params["encoder"], memory, ctx, cfg.remat)
    return h


def trunk(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray,
          memory: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S) int32; memory: (B, M, D) modality-frontend embeddings.

    Returns (hidden (B, S, D), aux_loss).
    """
    b, s = tokens.shape
    tokens = constrain(tokens, ("batch", None))
    x = layers.embed(params["embedding"], tokens)
    x = constrain(x, ("batch", None, None))
    stages = build_stages(cfg)
    memory = _run_encoder(params, cfg, stages, memory)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    ctx = Ctx(cfg=cfg, positions=pos, memory=memory)
    aux = 0.0
    for st in _decoder_stages(cfg, stages):
        x, a = st.apply(params[st.name], x, ctx, cfg.remat)
        aux = aux + a
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def forward(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray,
            memory: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence logits (B, S, V) — the prefill path."""
    h, _ = trunk(params, cfg, tokens, memory)
    logits = layers.unembed(params["embedding"], h)
    return constrain(logits, ("batch", None, "vocab"))


def encode(params: Pytree, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean-pooled embedding (B, EMBED_HEAD_DIM) — the ARCADE embedder path."""
    h, _ = trunk(params, cfg, tokens)
    pooled = jnp.mean(h.astype(jnp.float32), axis=1).astype(h.dtype)
    if "embed_head" in params:
        pooled = pooled @ params["embed_head"]
    emb = pooled.astype(jnp.float32)
    return emb / jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)


# ---------------------------------------------------------------------------
# loss (with optional MTP)
# ---------------------------------------------------------------------------

def loss_fn(params: Pytree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            mtp_weight: float = 0.3) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    tokens, labels = batch["tokens"], batch["labels"]
    memory = batch.get("memory")
    h, aux = trunk(params, cfg, tokens, memory)
    logits = layers.unembed(params["embedding"], h)
    logits = constrain(logits, ("batch", None, "vocab"))
    main = layers.softmax_xent(logits, labels, cfg.vocab_size)
    total = main + aux
    metrics = {"loss": main, "aux": jnp.asarray(aux, jnp.float32)}

    if cfg.mtp_depth:
        # DeepSeek-V3 MTP: combine h_t with emb(token_{t+1}) and predict
        # label_{t+1} (i.e. token t+2) through one extra block.
        emb_next = layers.embed(params["embedding"], tokens)
        h_in = jnp.concatenate(
            [layers.rms_normalize(h[:, :-1]),
             layers.rms_normalize(emb_next[:, 1:])], axis=-1)
        h_mtp = h_in @ params["mtp"]["proj"]
        b, sm, _ = h_mtp.shape
        pos = jnp.broadcast_to(jnp.arange(sm, dtype=jnp.int32)[None], (b, sm))
        blk = DenseBlock(cfg, use_moe=False,
                         d_ff=cfg.moe.dense_d_ff if cfg.moe else cfg.d_ff)
        h_mtp, _ = blk.apply(params["mtp"]["block"], h_mtp,
                             Ctx(cfg=cfg, positions=pos))
        mtp_logits = layers.unembed(params["embedding"], h_mtp)
        mtp_loss = layers.softmax_xent(mtp_logits, labels[:, 1:],
                                       cfg.vocab_size)
        total = total + mtp_weight * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    return total, metrics


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Tuple[Pytree, Pytree]:
    stages = build_stages(cfg)
    caches, axes = {}, {}
    for st in _decoder_stages(cfg, stages):
        c, a = st.init_cache(batch, max_seq)
        caches[st.name], axes[st.name] = c, a
    return caches, axes


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int) -> Pytree:
    box = {}

    def f():
        c, a = init_cache(cfg, batch, max_seq)
        box["axes"] = a
        return c

    shapes = jax.eval_shape(f)
    return shapes, box["axes"]


def decode_step(params: Pytree, cfg: ModelConfig, token: jnp.ndarray,
                cache: Pytree, pos,
                memory: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Pytree]:
    """One-token serve step. token: (B, 1) int32; pos: scalar int32 index.

    ``memory``: for audio, the *encoder output* (precomputed once at
    prefill — the decode step must not re-run the encoder per token);
    for vlm, the stubbed patch embeddings.
    """
    x = layers.embed(params["embedding"], token)
    stages = build_stages(cfg)
    ctx = Ctx(cfg=cfg, memory=memory, pos=pos)
    new_cache = {}
    for st in _decoder_stages(cfg, stages):
        x, c = st.decode(params[st.name], x, cache[st.name], ctx)
        new_cache[st.name] = c
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.unembed(params["embedding"], x)
    # mask padded-vocab tail so sampling/argmax never picks a pad id
    vp = logits.shape[-1]
    if vp > cfg.vocab_size:
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return logits, new_cache
